//! Reusable scratch buffers for the optimizer hot path.
//!
//! Every optimizer step needs a handful of temporaries (oriented gradient,
//! projected gradient, back-projection, update buffer). Allocating them per
//! step dominates the small-matrix regime; a [`Workspace`] instead keeps a
//! pool of retired buffers and hands them back out by *best-fit capacity*,
//! so a steady-state step performs zero heap allocations once the pool has
//! warmed up (see `tests/alloc_steady_state.rs` for the enforced proof).
//!
//! Ownership rules (also documented in ROADMAP.md §Hot-path architecture):
//!
//! * One `Workspace` per optimizer instance; it is transient compute
//!   scratch, never counted by `MemoryReport` (which tracks persistent
//!   optimizer *state*).
//! * `take(rows, cols)` returns a **zeroed** matrix; pair every `take` with
//!   a `give` in the same scope so the pool stays warm. Forgetting a `give`
//!   is not a leak — the buffer just gets reallocated next step.
//! * Buffers are plain `Vec`s; pools never shrink. Peak pool size equals
//!   the peak number of simultaneously-live temporaries per step.

use super::Matrix;

/// Best-fit pop: the pooled buffer with the smallest sufficient capacity.
/// First-fit would let a small request steal a large buffer and force the
/// next large request to allocate — best-fit keeps repeating request
/// patterns allocation-free.
///
/// This is also the telemetry tap for pool efficiency: a served request
/// counts as a hit, a fresh allocation as a miss with its byte size
/// (`obs::count_ws_pool_*`; since pools never shrink, cumulative miss
/// bytes equal the pool high-water mark).
fn pop_best_fit<T>(pool: &mut Vec<Vec<T>>, len: usize) -> Vec<T> {
    if len == 0 {
        return Vec::new();
    }
    let mut best: Option<(usize, usize)> = None; // (position, capacity)
    for (i, buf) in pool.iter().enumerate() {
        let cap = buf.capacity();
        if cap >= len {
            match best {
                Some((_, c)) if c <= cap => {}
                _ => best = Some((i, cap)),
            }
        }
    }
    match best {
        Some((i, _)) => {
            crate::obs::count_ws_pool_hit();
            pool.swap_remove(i)
        }
        None => {
            crate::obs::count_ws_pool_miss((len * std::mem::size_of::<T>()) as u64);
            Vec::with_capacity(len)
        }
    }
}

fn push_nonempty<T>(pool: &mut Vec<Vec<T>>, buf: Vec<T>) {
    // Zero-capacity buffers are free to recreate and would otherwise
    // accumulate (and re-grow the pool vec) every step.
    if buf.capacity() > 0 {
        pool.push(buf);
    }
}

/// Scratch-buffer pool backing the `_into` kernel family.
#[derive(Default)]
pub struct Workspace {
    f32_pool: Vec<Vec<f32>>,
    f64_pool: Vec<Vec<f64>>,
    usize_pool: Vec<Vec<usize>>,
}

impl Workspace {
    pub fn new() -> Self {
        Workspace::default()
    }

    /// Check out a zeroed `rows × cols` matrix.
    pub fn take(&mut self, rows: usize, cols: usize) -> Matrix {
        let len = rows * cols;
        let mut data = pop_best_fit(&mut self.f32_pool, len);
        data.clear();
        data.resize(len, 0.0);
        Matrix { rows, cols, data }
    }

    /// Check out a `rows × cols` matrix with **unspecified contents** — the
    /// non-zeroing twin of [`Workspace::take`] for buffers whose every
    /// element the caller overwrites before reading (`copy_from`,
    /// `transpose_into`, and the assign-style `_into` kernels that
    /// `resize_for_overwrite`). Skips the full memset per checkout that
    /// `take` pays; never hand one to an accumulate-in-place kernel.
    pub fn take_uninit(&mut self, rows: usize, cols: usize) -> Matrix {
        let len = rows * cols;
        let mut data = pop_best_fit(&mut self.f32_pool, len);
        // resize without clear: only growth beyond the buffer's previous
        // length is written; the reused prefix keeps stale values.
        data.resize(len, 0.0);
        Matrix { rows, cols, data }
    }

    /// Return a matrix's buffer to the pool.
    pub fn give(&mut self, m: Matrix) {
        push_nonempty(&mut self.f32_pool, m.data);
    }

    /// Check out a zeroed f32 buffer of `len`.
    pub fn take_f32(&mut self, len: usize) -> Vec<f32> {
        let mut v = pop_best_fit(&mut self.f32_pool, len);
        v.clear();
        v.resize(len, 0.0);
        v
    }

    pub fn give_f32(&mut self, v: Vec<f32>) {
        push_nonempty(&mut self.f32_pool, v);
    }

    /// Check out a zeroed f64 buffer of `len` (norm accumulators).
    pub fn take_f64(&mut self, len: usize) -> Vec<f64> {
        let mut v = pop_best_fit(&mut self.f64_pool, len);
        v.clear();
        v.resize(len, 0.0);
        v
    }

    pub fn give_f64(&mut self, v: Vec<f64>) {
        push_nonempty(&mut self.f64_pool, v);
    }

    /// Check out a zeroed usize buffer of `len` (index scratch).
    pub fn take_usize(&mut self, len: usize) -> Vec<usize> {
        let mut v = pop_best_fit(&mut self.usize_pool, len);
        v.clear();
        v.resize(len, 0);
        v
    }

    pub fn give_usize(&mut self, v: Vec<usize>) {
        push_nonempty(&mut self.usize_pool, v);
    }

    /// Number of pooled f32 buffers (test/diagnostic hook).
    pub fn pooled_f32_buffers(&self) -> usize {
        self.f32_pool.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_returns_zeroed_with_shape() {
        let mut ws = Workspace::new();
        let mut m = ws.take(3, 4);
        assert_eq!(m.shape(), (3, 4));
        assert!(m.data.iter().all(|&v| v == 0.0));
        m.data[5] = 7.0;
        ws.give(m);
        // reuse returns the same capacity, re-zeroed
        let m2 = ws.take(3, 4);
        assert!(m2.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn buffers_are_reused_not_grown() {
        let mut ws = Workspace::new();
        let m = ws.take(8, 8);
        let ptr = m.data.as_ptr();
        let cap = m.data.capacity();
        ws.give(m);
        let m2 = ws.take(4, 4); // smaller request reuses the same buffer
        assert_eq!(m2.data.as_ptr(), ptr);
        assert_eq!(m2.data.capacity(), cap);
        ws.give(m2);
        assert_eq!(ws.pooled_f32_buffers(), 1);
    }

    #[test]
    fn best_fit_prefers_smallest_sufficient() {
        let mut ws = Workspace::new();
        let big = ws.take(100, 1);
        let small = ws.take(10, 1);
        ws.give(big);
        ws.give(small);
        // a 10-element request must take the 10-cap buffer, not the 100-cap
        let got = ws.take(10, 1);
        assert!(got.data.capacity() < 100, "stole the big buffer");
        ws.give(got);
        // and the 100-element request still finds the big one → no alloc
        let got = ws.take(100, 1);
        assert!(got.data.capacity() >= 100);
    }

    #[test]
    fn take_uninit_reuses_without_memset() {
        let mut ws = Workspace::new();
        let mut m = ws.take(3, 3);
        m.data[4] = 42.0;
        ws.give(m);
        // uninit checkout may expose the stale value — shape is right, the
        // buffer is the pooled one, and contents are unspecified
        let m2 = ws.take_uninit(3, 3);
        assert_eq!(m2.shape(), (3, 3));
        assert_eq!(m2.data.len(), 9);
        assert_eq!(m2.data[4], 42.0, "expected the pooled buffer back");
        ws.give(m2);
        // growth beyond the previous length is still zero-filled
        let m3 = ws.take_uninit(4, 4);
        assert_eq!(m3.data.len(), 16);
        assert!(m3.data[9..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn zero_size_requests_do_not_pool() {
        let mut ws = Workspace::new();
        for _ in 0..10 {
            let m = ws.take(0, 5);
            ws.give(m);
        }
        assert_eq!(ws.pooled_f32_buffers(), 0);
    }

    #[test]
    fn typed_pools_are_independent() {
        let mut ws = Workspace::new();
        let f = ws.take_f64(16);
        let u = ws.take_usize(16);
        assert!(f.iter().all(|&v| v == 0.0));
        assert!(u.iter().all(|&v| v == 0));
        ws.give_f64(f);
        ws.give_usize(u);
        assert_eq!(ws.pooled_f32_buffers(), 0);
    }
}
