//! Row-major f32 matrix with the small op surface the optimizers need.
//! The column-norm accumulators run through the [`crate::simd`] lane layer
//! (lanes span distinct columns, so each column's ascending-row f64
//! accumulation order is untouched and every backend returns the same
//! bits).

use crate::simd::{Simd, F64_LANES};
use crate::util::Pcg64;

/// Dense row-major matrix. `data.len() == rows * cols`.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Pcg64) -> Self {
        let mut m = Self::zeros(rows, cols);
        rng.fill_normal(&mut m.data, std);
        m
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on big matrices.
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    /// Select columns: `self[:, idx]`.
    pub fn select_columns(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(self.rows, idx.len());
        self.select_columns_into(idx, &mut out);
        out
    }

    /// Allocation-free [`Matrix::select_columns`]: gathers into `out`,
    /// resizing it in place (no realloc once its capacity suffices).
    pub fn select_columns_into(&self, idx: &[usize], out: &mut Matrix) {
        out.resize_for_overwrite(self.rows, idx.len());
        for i in 0..self.rows {
            let src = self.row(i);
            let dst = &mut out.data[i * idx.len()..(i + 1) * idx.len()];
            for (k, &j) in idx.iter().enumerate() {
                dst[k] = src[j];
            }
        }
    }

    // -- in-place reshaping / copying (workspace hot path) ---------------

    /// Re-shape in place to `rows × cols`, zero-filling. Reuses the existing
    /// buffer whenever its capacity suffices — for accumulate-style kernels
    /// (`matmul_into`) that need a clean slate.
    pub fn resize_to(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    /// Re-shape in place *without* zero-filling the reused prefix — for
    /// assign-style kernels (`transpose_into`, `select_columns_into`,
    /// `matmul_a_bt_into`, the Makhoul row transform) that overwrite every
    /// element anyway; skips a full redundant memory pass per call.
    /// Contents are unspecified until the caller writes them.
    pub fn resize_for_overwrite(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    /// Become a copy of `other` (shape + data) without reallocating when
    /// capacity allows.
    pub fn copy_from(&mut self, other: &Matrix) {
        self.rows = other.rows;
        self.cols = other.cols;
        self.data.clear();
        self.data.extend_from_slice(&other.data);
    }

    /// Allocation-free transpose: `out = selfᵀ` (blocked like
    /// [`Matrix::transpose`]).
    pub fn transpose_into(&self, out: &mut Matrix) {
        out.resize_for_overwrite(self.cols, self.rows);
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
    }

    // -- elementwise / reductions ---------------------------------------

    pub fn scale(&mut self, a: f32) {
        for v in &mut self.data {
            *v *= a;
        }
    }

    pub fn scaled(&self, a: f32) -> Matrix {
        let mut out = self.clone();
        out.scale(a);
        out
    }

    /// `self += a * other`.
    pub fn axpy(&mut self, a: f32, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (x, y) in self.data.iter_mut().zip(&other.data) {
            *x += a * y;
        }
    }

    /// `self += a * otherᵀ` — lets callers apply a transposed update
    /// without materializing the transpose (blocked for cache locality).
    pub fn axpy_t(&mut self, a: f32, other: &Matrix) {
        assert_eq!(
            (self.rows, self.cols),
            (other.cols, other.rows),
            "axpy_t shape mismatch"
        );
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        self.data[i * self.cols + j] +=
                            a * other.data[j * other.cols + i];
                    }
                }
            }
        }
    }

    /// `self = minuend − self`, elementwise in place — used to turn a
    /// back-projection buffer into the error-feedback residual `G − Ĝ`
    /// without a third matrix.
    pub fn sub_from(&mut self, minuend: &Matrix) {
        assert_eq!(self.shape(), minuend.shape());
        for (x, m) in self.data.iter_mut().zip(&minuend.data) {
            *x = m - *x;
        }
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        let mut out = self.clone();
        out.axpy(1.0, other);
        out
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        let mut out = self.clone();
        out.axpy(-1.0, other);
        out
    }

    pub fn fro_norm_sq(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum()
    }

    pub fn fro_norm(&self) -> f64 {
        self.fro_norm_sq().sqrt()
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    /// Per-column squared-ℓ2 sums, f64-accumulated into `acc` (overwritten).
    /// The single accumulation kernel behind [`Matrix::col_l2_norms`] and
    /// `projection::select_top_columns_into` — sharing it keeps their
    /// rankings bit-equivalent by construction (row-major pass, ascending
    /// rows, one f64 add per element).
    pub fn col_sq_sums_into(&self, acc: &mut [f64]) {
        assert_eq!(acc.len(), self.cols, "col_sq_sums_into length mismatch");
        col_sq_sums_kernel(&self.data, self.rows, self.cols, acc);
    }

    /// Per-column absolute sums (ℓ1), f64-accumulated into `acc`
    /// (overwritten). Shared like [`Matrix::col_sq_sums_into`].
    pub fn col_abs_sums_into(&self, acc: &mut [f64]) {
        assert_eq!(acc.len(), self.cols, "col_abs_sums_into length mismatch");
        col_abs_sums_kernel(&self.data, self.rows, self.cols, acc);
    }

    /// Per-column ℓ2 norms.
    pub fn col_l2_norms(&self) -> Vec<f32> {
        let mut acc = vec![0.0f64; self.cols];
        self.col_sq_sums_into(&mut acc);
        acc.into_iter().map(|v| v.sqrt() as f32).collect()
    }

    /// Per-column ℓ1 norms.
    pub fn col_l1_norms(&self) -> Vec<f32> {
        let mut acc = vec![0.0f64; self.cols];
        self.col_abs_sums_into(&mut acc);
        acc.into_iter().map(|v| v as f32).collect()
    }

    /// Max absolute elementwise difference (test helper).
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (&a, &b)| m.max((a - b).abs()))
    }

    /// Memory footprint of the buffer in bytes (for the memory reports).
    pub fn bytes(&self) -> u64 {
        (self.data.len() * std::mem::size_of::<f32>()) as u64
    }
}

/// Shared column-accumulation kernel behind [`Matrix::col_sq_sums_into`]:
/// one row-major pass (the matrix is streamed once, like the scalar
/// original — the f64 accumulator row is small enough to stay L1-resident)
/// with 4-column lane groups: one exact f32→f64 widen + one multiply + one
/// add per element, ascending rows — the exact scalar order, so every
/// backend returns the same bits as the pre-SIMD kernel.
#[inline(always)]
fn col_sq_sums_g<S: Simd>(data: &[f32], rows: usize, cols: usize, acc: &mut [f64]) {
    acc.fill(0.0);
    for i in 0..rows {
        let row = &data[i * cols..(i + 1) * cols];
        let mut j = 0;
        while j + F64_LANES <= cols {
            let w = S::widen4(&row[j..]);
            let a = S::add64(S::load64(&acc[j..]), S::mul64(w, w));
            S::store64(&mut acc[j..], a);
            j += F64_LANES;
        }
        while j < cols {
            let v = row[j] as f64;
            acc[j] += v * v;
            j += 1;
        }
    }
}

crate::simd_dispatch! {
    fn col_sq_sums_kernel(data: &[f32], rows: usize, cols: usize, acc: &mut [f64])
        = col_sq_sums_g
}

/// ℓ1 twin of [`col_sq_sums_g`] (`|v|` is a sign-bit clear after the exact
/// widen, so it commutes with the conversion and matches the historical
/// `v.abs() as f64` bits).
#[inline(always)]
fn col_abs_sums_g<S: Simd>(data: &[f32], rows: usize, cols: usize, acc: &mut [f64]) {
    acc.fill(0.0);
    for i in 0..rows {
        let row = &data[i * cols..(i + 1) * cols];
        let mut j = 0;
        while j + F64_LANES <= cols {
            let w = S::widen4(&row[j..]);
            let a = S::add64(S::load64(&acc[j..]), S::abs64(w));
            S::store64(&mut acc[j..], a);
            j += F64_LANES;
        }
        while j < cols {
            acc[j] += (row[j] as f64).abs();
            j += 1;
        }
    }
}

crate::simd_dispatch! {
    fn col_abs_sums_kernel(data: &[f32], rows: usize, cols: usize, acc: &mut [f64])
        = col_abs_sums_g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let m = Matrix::from_fn(2, 3, |i, j| (i * 10 + j) as f32);
        assert_eq!(m.at(1, 2), 12.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0]);
        assert_eq!(m.shape(), (2, 3));
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::seed(0);
        let m = Matrix::randn(37, 53, 1.0, &mut rng);
        let tt = m.transpose().transpose();
        assert_eq!(m, tt);
        assert_eq!(m.at(3, 7), m.transpose().at(7, 3));
    }

    #[test]
    fn select_columns_matches_manual() {
        let m = Matrix::from_fn(3, 5, |i, j| (i * 5 + j) as f32);
        let s = m.select_columns(&[4, 0, 2]);
        assert_eq!(s.row(1), &[9.0, 5.0, 7.0]);
    }

    #[test]
    fn axpy_and_norms() {
        let mut a = Matrix::from_vec(1, 3, vec![1.0, 2.0, 2.0]);
        let b = Matrix::from_vec(1, 3, vec![1.0, 1.0, 1.0]);
        a.axpy(2.0, &b);
        assert_eq!(a.data, vec![3.0, 4.0, 4.0]);
        assert!((a.fro_norm() - (41.0f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn column_norms() {
        let m = Matrix::from_vec(2, 2, vec![3.0, -1.0, 4.0, 1.0]);
        let l2 = m.col_l2_norms();
        assert!((l2[0] - 5.0).abs() < 1e-6);
        let l1 = m.col_l1_norms();
        assert!((l1[1] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn into_variants_match_allocating() {
        let mut rng = Pcg64::seed(7);
        let m = Matrix::randn(9, 13, 1.0, &mut rng);
        // dirty, wrongly-shaped output buffers must be fully overwritten
        let mut out = Matrix::from_vec(1, 3, vec![9.0, 9.0, 9.0]);
        m.transpose_into(&mut out);
        assert_eq!(out, m.transpose());
        let idx = [12usize, 0, 5, 5];
        m.select_columns_into(&idx, &mut out);
        assert_eq!(out, m.select_columns(&idx));
        out.copy_from(&m);
        assert_eq!(out, m);
    }

    #[test]
    fn axpy_t_matches_transpose_axpy() {
        let mut rng = Pcg64::seed(8);
        let base = Matrix::randn(6, 11, 1.0, &mut rng);
        let other = Matrix::randn(11, 6, 1.0, &mut rng);
        let mut a = base.clone();
        a.axpy_t(0.7, &other);
        let mut b = base;
        b.axpy(0.7, &other.transpose());
        assert_eq!(a, b);
    }

    #[test]
    fn sub_from_is_reverse_subtraction() {
        let g = Matrix::from_vec(1, 3, vec![5.0, 1.0, -2.0]);
        let mut back = Matrix::from_vec(1, 3, vec![1.0, 1.0, 1.0]);
        back.sub_from(&g);
        assert_eq!(back.data, vec![4.0, 0.0, -3.0]);
    }

    #[test]
    fn resize_to_reuses_capacity() {
        let mut m = Matrix::zeros(10, 10);
        let ptr = m.data.as_ptr();
        m.resize_to(4, 6);
        assert_eq!(m.shape(), (4, 6));
        assert_eq!(m.data.as_ptr(), ptr);
        assert!(m.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn eye_is_identity_under_select() {
        let e = Matrix::eye(4);
        let sel = e.select_columns(&[2, 3]);
        assert_eq!(sel.at(2, 0), 1.0);
        assert_eq!(sel.at(3, 1), 1.0);
        assert_eq!(sel.at(0, 0), 0.0);
    }
}
