//! Typed optimizer-state storage: one handle, pluggable precision.
//!
//! Every piece of *persistent* optimizer state (Adam moments, Newton–Schulz
//! momentum, error-feedback residuals, the dense-AdamW fallback moments)
//! lives in a [`StateStore`] — a `rows × cols` tensor stored as one of
//! three [`StateDtype`]s:
//!
//! | dtype | storage | semantics |
//! |-------|---------|-----------|
//! | `F32`  | `Vec<f32>` (4 B/elem) | exact — a zero-cost passthrough |
//! | `Bf16` | `Vec<u16>` (2 B/elem) | round-to-nearest-even truncation (`tensor::bf16`) |
//! | `Q8`   | `Vec<i8>` + f32 scale (1 B/elem + 4 B) | MicroAdam-style symmetric per-tensor quantization |
//!
//! Compute always happens in f32: the owning policy checks the state out
//! ([`StateStore::checkout`]), mutates the f32 matrix, and commits it back
//! ([`StateStore::commit`]). The F32 store hands out its backing buffer by
//! move (two pointer swaps — no copy, no rounding), which is what makes the
//! default dtype **bit-invisible**: all six engine presets stay bit-identical
//! to the pre-store code (`tests/engine_equivalence.rs`, unchanged). Lower
//! precisions stage through [`Workspace`] scratch, so steady-state steps
//! remain allocation-free for every dtype (`tests/alloc_steady_state.rs`).
//!
//! The de/quantization inner loops are `simd_dispatch!` kernels
//! ([`bf16_pack_into`], [`bf16_unpack_into`], [`q8_quantize_into`],
//! [`q8_dequantize_into`] and the fused `*_add_into` replay variants) with
//! bit-identical scalar tails, pinned in `tests/simd_bit_identity.rs`. The
//! Q8 arithmetic is exactly the historical `EfBuffer` Q8 arithmetic
//! (`scale = |x|max/127 + 1e-12`, round-half-away, clamp ±127), so the
//! DCT-AdamW preset's quantized error feedback is bit-identical to the
//! pre-store implementation by construction. The same kernel pair also
//! backs the `wire=q8` collectives encoding
//! (`coordinator::compressed::q8_wire_encode`): one quantizer, one set of
//! pinned semantics, whether the bytes persist in optimizer state or ride
//! the ring.
//!
//! Stores serialize bit-exactly ([`StateStore::save`] /
//! [`StateStore::load_from`]) — the substrate of the checkpoint-v2 resume
//! contract (`train::checkpoint`).

use anyhow::{ensure, Result};

use crate::simd::{Simd, F32_LANES};
use crate::tensor::bf16::{bf16_bits_to_f32, f32_to_bf16_bits};
use crate::tensor::{Matrix, Workspace};
use crate::util::codec::{self, ByteReader};

/// Storage precision of a [`StateStore`] — the fifth composition axis of
/// `OptimizerSpec` (`state-dtype=f32|bf16|q8`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StateDtype {
    F32,
    Bf16,
    Q8,
}

impl StateDtype {
    pub fn parse(s: &str) -> Option<StateDtype> {
        Some(match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" => StateDtype::F32,
            "bf16" | "bfloat16" => StateDtype::Bf16,
            "q8" | "int8" => StateDtype::Q8,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            StateDtype::F32 => "f32",
            StateDtype::Bf16 => "bf16",
            StateDtype::Q8 => "q8",
        }
    }

    /// Test/CI hook: the `FFT_SUBSPACE_STATE_DTYPE` sweep knob
    /// (`make test-matrix` runs the engine suites under f32 and bf16).
    pub fn from_env() -> Option<StateDtype> {
        std::env::var("FFT_SUBSPACE_STATE_DTYPE")
            .ok()
            .and_then(|v| StateDtype::parse(v.trim()))
    }

    fn tag(self) -> u8 {
        match self {
            StateDtype::F32 => 0,
            StateDtype::Bf16 => 1,
            StateDtype::Q8 => 2,
        }
    }
}

/// One persistent optimizer-state tensor behind a typed handle.
#[derive(Clone, Debug)]
pub enum StateStore {
    F32(Matrix),
    Bf16 { rows: usize, cols: usize, data: Vec<u16> },
    Q8 { rows: usize, cols: usize, q: Vec<i8>, scale: f32 },
}

impl StateStore {
    /// A zero-initialized `rows × cols` store.
    pub fn zeros(dtype: StateDtype, rows: usize, cols: usize) -> StateStore {
        match dtype {
            StateDtype::F32 => StateStore::F32(Matrix::zeros(rows, cols)),
            StateDtype::Bf16 => StateStore::Bf16 { rows, cols, data: vec![0; rows * cols] },
            StateDtype::Q8 => StateStore::Q8 { rows, cols, q: vec![0; rows * cols], scale: 0.0 },
        }
    }

    pub fn dtype(&self) -> StateDtype {
        match self {
            StateStore::F32(_) => StateDtype::F32,
            StateStore::Bf16 { .. } => StateDtype::Bf16,
            StateStore::Q8 { .. } => StateDtype::Q8,
        }
    }

    pub fn shape(&self) -> (usize, usize) {
        match self {
            StateStore::F32(m) => m.shape(),
            StateStore::Bf16 { rows, cols, .. } | StateStore::Q8 { rows, cols, .. } => {
                (*rows, *cols)
            }
        }
    }

    /// True persistent bytes of this store — what [`MemoryReport`] counts
    /// (the measurable side of the paper's memory claim).
    ///
    /// [`MemoryReport`]: crate::optim::MemoryReport
    pub fn bytes(&self) -> u64 {
        match self {
            StateStore::F32(m) => m.bytes(),
            StateStore::Bf16 { data, .. } => (data.len() * 2) as u64,
            StateStore::Q8 { q, .. } => q.len() as u64 + 4,
        }
    }

    /// Materialize the state into `out` (resized in place, every element
    /// overwritten).
    pub fn load_into(&self, out: &mut Matrix) {
        let (rows, cols) = self.shape();
        out.resize_for_overwrite(rows, cols);
        match self {
            StateStore::F32(m) => out.data.copy_from_slice(&m.data),
            StateStore::Bf16 { data, .. } => bf16_unpack_into(&mut out.data, data),
            StateStore::Q8 { q, scale, .. } => q8_dequantize_into(&mut out.data, q, *scale),
        }
    }

    /// Store `m` (same shape), rounding/quantizing per the dtype.
    pub fn store_from(&mut self, m: &Matrix) {
        assert_eq!(self.shape(), m.shape(), "StateStore::store_from shape mismatch");
        match self {
            StateStore::F32(slot) => slot.data.copy_from_slice(&m.data),
            StateStore::Bf16 { data, .. } => bf16_pack_into(data, &m.data),
            StateStore::Q8 { q, scale, .. } => {
                // exact historical EfBuffer-Q8 arithmetic (bit-compat)
                let s = m.abs_max() / 127.0 + 1e-12;
                *scale = s;
                q8_quantize_into(q, &m.data, s);
            }
        }
    }

    /// `g += state`, without materializing the state (error-feedback
    /// replay). The op sequence per element matches the historical EF
    /// buffers exactly: f32 adds the value, bf16 adds the exact f32
    /// expansion, Q8 adds `q·scale` (skipped entirely while `scale == 0`,
    /// i.e. before the first store).
    pub fn add_into(&self, g: &mut Matrix) {
        assert_eq!(self.shape(), g.shape(), "StateStore::add_into shape mismatch");
        match self {
            StateStore::F32(m) => g.axpy(1.0, m),
            StateStore::Bf16 { data, .. } => bf16_add_into(&mut g.data, data),
            StateStore::Q8 { q, scale, .. } => {
                if *scale != 0.0 {
                    q8_add_into(&mut g.data, q, *scale);
                }
            }
        }
    }

    /// Check the state out as a mutable f32 matrix for this step's compute.
    ///
    /// F32 stores hand their backing matrix out **by move** (no copy — the
    /// zero-cost passthrough); other dtypes dequantize into a pooled
    /// scratch matrix. Pair every checkout with [`StateStore::commit`] in
    /// the same scope.
    pub fn checkout(&mut self, ws: &mut Workspace) -> Matrix {
        match self {
            StateStore::F32(m) => std::mem::replace(m, Matrix { rows: 0, cols: 0, data: Vec::new() }),
            other => {
                let (rows, cols) = other.shape();
                let mut buf = ws.take_uninit(rows, cols);
                other.load_into(&mut buf);
                buf
            }
        }
    }

    /// Return a checked-out matrix: F32 moves it back in place, other
    /// dtypes re-quantize and return the scratch buffer to the pool.
    pub fn commit(&mut self, m: Matrix, ws: &mut Workspace) {
        match self {
            StateStore::F32(slot) => {
                debug_assert_eq!(slot.data.len(), 0, "commit without checkout");
                *slot = m;
            }
            other => {
                other.store_from(&m);
                ws.give(m);
            }
        }
    }

    /// Borrow the f32 backing matrix (F32 stores only) — test hook.
    pub fn as_f32(&self) -> Option<&Matrix> {
        match self {
            StateStore::F32(m) => Some(m),
            _ => None,
        }
    }

    /// Mutable twin of [`StateStore::as_f32`] — test hook.
    pub fn as_f32_mut(&mut self) -> Option<&mut Matrix> {
        match self {
            StateStore::F32(m) => Some(m),
            _ => None,
        }
    }

    /// Materialize to an owned f32 matrix (allocating) — test and
    /// instrumentation hook, not a hot-path method.
    pub fn to_matrix(&self) -> Matrix {
        let (rows, cols) = self.shape();
        let mut out = Matrix::zeros(rows, cols);
        self.load_into(&mut out);
        out
    }

    // ---- checkpoint serialization (bit-exact) --------------------------

    /// Serialize dtype tag + shape + the raw payload (checkpoint v2).
    pub fn save(&self, out: &mut Vec<u8>) {
        codec::put_u8(out, self.dtype().tag());
        let (rows, cols) = self.shape();
        codec::put_u32(out, rows as u32);
        codec::put_u32(out, cols as u32);
        match self {
            StateStore::F32(m) => codec::put_f32s(out, &m.data),
            StateStore::Bf16 { data, .. } => codec::put_u16s(out, data),
            StateStore::Q8 { q, scale, .. } => {
                codec::put_f32(out, *scale);
                codec::put_i8s(out, q);
            }
        }
    }

    /// Restore a payload written by [`StateStore::save`] into this store.
    /// Errors if the checkpointed dtype or shape disagrees with the built
    /// spec — resuming requires the identical composition.
    pub fn load_from(&mut self, r: &mut ByteReader) -> Result<()> {
        let tag = r.take_u8()?;
        ensure!(
            tag == self.dtype().tag(),
            "checkpointed state dtype tag {tag} != configured {} — resume \
             with the same state-dtype the run was saved with",
            self.dtype().name()
        );
        let rows = r.take_u32()? as usize;
        let cols = r.take_u32()? as usize;
        ensure!(
            (rows, cols) == self.shape(),
            "checkpointed state is {rows}x{cols}, expected {:?}",
            self.shape()
        );
        match self {
            StateStore::F32(m) => r.take_f32s_into(&mut m.data)?,
            StateStore::Bf16 { data, .. } => r.take_u16s_into(data)?,
            StateStore::Q8 { q, scale, .. } => {
                *scale = r.take_f32()?;
                r.take_i8s_into(q)?;
            }
        }
        Ok(())
    }
}

// ---- SIMD pack/unpack kernels ------------------------------------------
//
// All four follow the simd bit-identity contract: bit manipulations and
// single correctly-rounded IEEE ops per lane, lanes span independent
// elements, remainders run the identical scalar sequence.

/// f32 → bf16 with round-to-nearest-even; lane-for-lane the bit recipe of
/// [`f32_to_bf16_bits`] (NaN lanes quieted via the unordered-compare mask).
#[inline(always)]
fn bf16_pack_g<S: Simd>(dst: &mut [u16], src: &[f32]) {
    let n = dst.len();
    debug_assert_eq!(src.len(), n);
    let (c1, c7fff, c40) = (S::splat_u32(1), S::splat_u32(0x7FFF), S::splat_u32(0x40));
    let mut k = 0;
    while k + F32_LANES <= n {
        let v = S::load(&src[k..]);
        let bits = S::f32_bits(v);
        let hi = S::shr16_u32(bits);
        let lsb = S::and_u32(hi, c1);
        let rne = S::shr16_u32(S::add_u32(bits, S::add_u32(lsb, c7fff)));
        let nan = S::or_u32(hi, c40);
        let res = S::to_array_u32(S::select_u32(S::nan_mask_u32(v), nan, rne));
        for (d, &r) in dst[k..k + F32_LANES].iter_mut().zip(res.iter()) {
            *d = r as u16;
        }
        k += F32_LANES;
    }
    while k < n {
        dst[k] = f32_to_bf16_bits(src[k]);
        k += 1;
    }
}

crate::simd_dispatch! {
    /// See [`bf16_pack_g`]; `dst` and `src` must be equal length.
    pub fn bf16_pack_into(dst: &mut [u16], src: &[f32]) = bf16_pack_g
}

/// bf16 → f32 (exact: widen + shift + reinterpret).
#[inline(always)]
fn bf16_unpack_g<S: Simd>(dst: &mut [f32], src: &[u16]) {
    let n = dst.len();
    debug_assert_eq!(src.len(), n);
    let mut k = 0;
    while k + F32_LANES <= n {
        let v = S::bits_f32(S::shl16_u32(S::widen_u16(&src[k..])));
        S::store(&mut dst[k..], v);
        k += F32_LANES;
    }
    while k < n {
        dst[k] = bf16_bits_to_f32(src[k]);
        k += 1;
    }
}

crate::simd_dispatch! {
    /// See [`bf16_unpack_g`]; `dst` and `src` must be equal length.
    pub fn bf16_unpack_into(dst: &mut [f32], src: &[u16]) = bf16_unpack_g
}

/// `dst += bf16(src)` — fused EF replay (the expansion is exact, the add is
/// the single correctly-rounded op the scalar loop performs).
#[inline(always)]
fn bf16_add_g<S: Simd>(dst: &mut [f32], src: &[u16]) {
    let n = dst.len();
    debug_assert_eq!(src.len(), n);
    let mut k = 0;
    while k + F32_LANES <= n {
        let e = S::bits_f32(S::shl16_u32(S::widen_u16(&src[k..])));
        let g = S::add(S::load(&dst[k..]), e);
        S::store(&mut dst[k..], g);
        k += F32_LANES;
    }
    while k < n {
        dst[k] += bf16_bits_to_f32(src[k]);
        k += 1;
    }
}

crate::simd_dispatch! {
    /// See [`bf16_add_g`]; `dst` and `src` must be equal length.
    pub fn bf16_add_into(dst: &mut [f32], src: &[u16]) = bf16_add_g
}

/// Symmetric int8 quantization `q = clamp(round(v/scale), ±127)`.
///
/// The division is the only floating-point operation and runs vectorized
/// (correctly rounded, so bit-identical per lane); `round` is Rust's
/// half-away-from-zero, which no single vector instruction reproduces, so
/// rounding/clamping/narrowing stay scalar per element — the exact op
/// sequence of the historical Q8 EF buffer.
#[inline(always)]
fn q8_quantize_g<S: Simd>(q: &mut [i8], src: &[f32], scale: f32) {
    let n = q.len();
    debug_assert_eq!(src.len(), n);
    let sv = S::splat(scale);
    let mut k = 0;
    while k + F32_LANES <= n {
        let d = S::to_array(S::div(S::load(&src[k..]), sv));
        for (qv, &dv) in q[k..k + F32_LANES].iter_mut().zip(d.iter()) {
            *qv = dv.round().clamp(-127.0, 127.0) as i8;
        }
        k += F32_LANES;
    }
    while k < n {
        q[k] = (src[k] / scale).round().clamp(-127.0, 127.0) as i8;
        k += 1;
    }
}

crate::simd_dispatch! {
    /// See [`q8_quantize_g`]; `q` and `src` must be equal length.
    pub fn q8_quantize_into(q: &mut [i8], src: &[f32], scale: f32) = q8_quantize_g
}

/// Dequantize `dst = q·scale` (exact i8→f32 widen, vector multiply).
#[inline(always)]
fn q8_dequantize_g<S: Simd>(dst: &mut [f32], q: &[i8], scale: f32) {
    let n = dst.len();
    debug_assert_eq!(q.len(), n);
    let sv = S::splat(scale);
    let mut k = 0;
    while k + F32_LANES <= n {
        let mut w = [0.0f32; F32_LANES];
        for (wv, &qv) in w.iter_mut().zip(&q[k..k + F32_LANES]) {
            *wv = qv as f32; // exact conversion
        }
        S::store(&mut dst[k..], S::mul(S::load(&w), sv));
        k += F32_LANES;
    }
    while k < n {
        dst[k] = q[k] as f32 * scale;
        k += 1;
    }
}

crate::simd_dispatch! {
    /// See [`q8_dequantize_g`]; `dst` and `q` must be equal length.
    pub fn q8_dequantize_into(dst: &mut [f32], q: &[i8], scale: f32) = q8_dequantize_g
}

/// `dst += q·scale` — fused Q8 EF replay (product then add, two correctly
/// rounded ops, exactly the scalar `*gv += qv as f32 * scale`).
#[inline(always)]
fn q8_add_g<S: Simd>(dst: &mut [f32], q: &[i8], scale: f32) {
    let n = dst.len();
    debug_assert_eq!(q.len(), n);
    let sv = S::splat(scale);
    let mut k = 0;
    while k + F32_LANES <= n {
        let mut w = [0.0f32; F32_LANES];
        for (wv, &qv) in w.iter_mut().zip(&q[k..k + F32_LANES]) {
            *wv = qv as f32;
        }
        let g = S::add(S::load(&dst[k..]), S::mul(S::load(&w), sv));
        S::store(&mut dst[k..], g);
        k += F32_LANES;
    }
    while k < n {
        dst[k] += q[k] as f32 * scale;
        k += 1;
    }
}

crate::simd_dispatch! {
    /// See [`q8_add_g`]; `dst` and `q` must be equal length.
    pub fn q8_add_into(dst: &mut [f32], q: &[i8], scale: f32) = q8_add_g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::bf16::round_bf16;
    use crate::util::Pcg64;

    #[test]
    fn f32_checkout_is_zero_copy_and_exact() {
        let mut rng = Pcg64::seed(0);
        let m = Matrix::randn(5, 7, 1.0, &mut rng);
        let mut st = StateStore::zeros(StateDtype::F32, 5, 7);
        st.store_from(&m);
        let mut ws = Workspace::new();
        let out = st.checkout(&mut ws);
        let ptr = out.data.as_ptr();
        assert_eq!(out, m);
        st.commit(out, &mut ws);
        // the same buffer came back — no copy, no pool traffic
        assert_eq!(st.as_f32().unwrap().data.as_ptr(), ptr);
        assert_eq!(ws.pooled_f32_buffers(), 0);
        assert_eq!(st.bytes(), 5 * 7 * 4);
    }

    #[test]
    fn bf16_roundtrips_through_rne() {
        let mut rng = Pcg64::seed(1);
        let m = Matrix::randn(6, 9, 3.0, &mut rng);
        let mut st = StateStore::zeros(StateDtype::Bf16, 6, 9);
        st.store_from(&m);
        assert_eq!(st.bytes(), 6 * 9 * 2);
        let back = st.to_matrix();
        for (b, &v) in back.data.iter().zip(m.data.iter()) {
            assert_eq!(b.to_bits(), round_bf16(v).to_bits());
        }
    }

    #[test]
    fn q8_matches_legacy_ef_arithmetic() {
        // the exact scale/round/clamp sequence of the historical EfBuffer
        let mut rng = Pcg64::seed(2);
        let m = Matrix::randn(8, 9, 1.0, &mut rng);
        let mut st = StateStore::zeros(StateDtype::Q8, 8, 9);
        st.store_from(&m);
        let s = m.abs_max() / 127.0 + 1e-12;
        let mut g = Matrix::zeros(8, 9);
        st.add_into(&mut g);
        for (gv, &mv) in g.data.iter().zip(m.data.iter()) {
            let want = (mv / s).round().clamp(-127.0, 127.0) as i8 as f32 * s;
            assert_eq!(gv.to_bits(), want.to_bits());
        }
        // error bound: half a quantization step
        assert!(g.max_abs_diff(&m) <= s * 0.5 + 1e-6);
        assert_eq!(st.bytes(), 8 * 9 + 4);
    }

    #[test]
    fn fresh_q8_add_into_is_noop() {
        let st = StateStore::zeros(StateDtype::Q8, 3, 3);
        let mut g = Matrix::from_vec(3, 3, vec![1.0; 9]);
        st.add_into(&mut g);
        assert_eq!(g.data, vec![1.0; 9]);
    }

    #[test]
    fn checkout_commit_stages_through_workspace() {
        let mut rng = Pcg64::seed(3);
        let m = Matrix::randn(4, 11, 1.0, &mut rng);
        for dtype in [StateDtype::Bf16, StateDtype::Q8] {
            let mut st = StateStore::zeros(dtype, 4, 11);
            let mut ws = Workspace::new();
            let mut out = st.checkout(&mut ws);
            assert!(out.data.iter().all(|&v| v == 0.0), "{dtype:?} not zero-init");
            out.copy_from(&m);
            st.commit(out, &mut ws);
            // buffer returned to the pool, state persisted lossily
            assert_eq!(ws.pooled_f32_buffers(), 1);
            let back = st.to_matrix();
            let tol = match dtype {
                StateDtype::Bf16 => m.abs_max() / 128.0,
                _ => m.abs_max() / 127.0 * 0.51 + 1e-6,
            };
            assert!(back.max_abs_diff(&m) <= tol, "{dtype:?}: {}", back.max_abs_diff(&m));
        }
    }

    #[test]
    fn save_load_roundtrip_is_bit_exact() {
        let mut rng = Pcg64::seed(4);
        let m = Matrix::randn(5, 6, 2.0, &mut rng);
        for dtype in [StateDtype::F32, StateDtype::Bf16, StateDtype::Q8] {
            let mut st = StateStore::zeros(dtype, 5, 6);
            st.store_from(&m);
            let before = st.to_matrix();
            let mut blob = Vec::new();
            st.save(&mut blob);
            let mut fresh = StateStore::zeros(dtype, 5, 6);
            let mut r = ByteReader::new(&blob);
            fresh.load_from(&mut r).unwrap();
            r.finish().unwrap();
            let after = fresh.to_matrix();
            assert_eq!(
                before.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                after.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{dtype:?}"
            );
        }
    }

    #[test]
    fn load_rejects_dtype_and_shape_mismatch() {
        let st = StateStore::zeros(StateDtype::Bf16, 2, 2);
        let mut blob = Vec::new();
        st.save(&mut blob);
        let mut wrong_dtype = StateStore::zeros(StateDtype::F32, 2, 2);
        assert!(wrong_dtype.load_from(&mut ByteReader::new(&blob)).is_err());
        let mut wrong_shape = StateStore::zeros(StateDtype::Bf16, 2, 3);
        assert!(wrong_shape.load_from(&mut ByteReader::new(&blob)).is_err());
    }

    #[test]
    fn dtype_parsing() {
        assert_eq!(StateDtype::parse("f32"), Some(StateDtype::F32));
        assert_eq!(StateDtype::parse("BF16"), Some(StateDtype::Bf16));
        assert_eq!(StateDtype::parse("q8"), Some(StateDtype::Q8));
        assert_eq!(StateDtype::parse("q4"), None);
    }

    #[test]
    fn pack_kernels_match_scalar_reference_on_edge_values() {
        let vals = [
            0.0f32,
            -0.0,
            1.0,
            -2.5,
            f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::MIN_POSITIVE / 2.0, // subnormal
            3.0e38,
            1.0 + f32::EPSILON,
        ];
        let mut packed = vec![0u16; vals.len()];
        bf16_pack_into(&mut packed, &vals);
        for (&p, &v) in packed.iter().zip(vals.iter()) {
            assert_eq!(p, f32_to_bf16_bits(v), "{v}");
        }
        let mut un = vec![0.0f32; vals.len()];
        bf16_unpack_into(&mut un, &packed);
        for (&u, &p) in un.iter().zip(packed.iter()) {
            assert_eq!(u.to_bits(), bf16_bits_to_f32(p).to_bits());
        }
    }
}
