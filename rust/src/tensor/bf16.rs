//! bfloat16 storage emulation.
//!
//! The paper's Table 5 compares Makhoul-in-float32 against matmul-in-bfloat16
//! (PyTorch lacks complex-bf16, so the FFT path is fp32-only). We reproduce
//! the *storage* semantics exactly — round-to-nearest-even truncation of the
//! mantissa — and model the bf16 throughput advantage in the bench harness
//! (DESIGN.md §Hardware-Adaptation: no bf16 ALUs on this CPU).

use super::Matrix;

/// f32 → bf16 bits with round-to-nearest-even.
#[inline]
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) | 0x0040) as u16; // quiet the NaN
    }
    let lsb = (bits >> 16) & 1;
    (bits.wrapping_add(0x0000_7FFF + lsb) >> 16) as u16
}

/// bf16 bits → f32 (exact).
#[inline]
pub fn bf16_bits_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Round a value through bf16 storage.
#[inline]
pub fn round_bf16(x: f32) -> f32 {
    bf16_bits_to_f32(f32_to_bf16_bits(x))
}

/// A matrix stored in bf16 (2 bytes/element) that computes in f32.
#[derive(Clone, Debug)]
pub struct Bf16Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<u16>,
}

impl Bf16Matrix {
    pub fn from_f32(m: &Matrix) -> Self {
        Bf16Matrix {
            rows: m.rows,
            cols: m.cols,
            data: m.data.iter().map(|&v| f32_to_bf16_bits(v)).collect(),
        }
    }

    pub fn to_f32(&self) -> Matrix {
        Matrix::from_vec(
            self.rows,
            self.cols,
            self.data.iter().map(|&b| bf16_bits_to_f32(b)).collect(),
        )
    }

    pub fn bytes(&self) -> u64 {
        (self.data.len() * 2) as u64
    }
}

/// `A·B` where both operands are bf16-stored (computed in f32, result
/// rounded back through bf16 — mirrors tensor-core accumulate-then-store).
pub fn matmul_bf16(a: &Bf16Matrix, b: &Bf16Matrix) -> Matrix {
    let af = a.to_f32();
    let bf = b.to_f32();
    let mut c = super::matmul(&af, &bf);
    for v in &mut c.data {
        *v = round_bf16(*v);
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn exact_for_representable_values() {
        for v in [0.0f32, 1.0, -2.0, 0.5, 256.0, -0.125] {
            assert_eq!(round_bf16(v), v);
        }
    }

    #[test]
    fn relative_error_bounded() {
        let mut rng = Pcg64::seed(0);
        for _ in 0..1000 {
            let x = (rng.normal_f32()) * 100.0;
            if x == 0.0 {
                continue;
            }
            let r = round_bf16(x);
            // bf16 has 8 significand bits → rel err ≤ 2^-8
            assert!(((r - x) / x).abs() <= 1.0 / 256.0 + 1e-7, "x={x} r={r}");
        }
    }

    #[test]
    fn nan_and_inf_preserved() {
        assert!(round_bf16(f32::NAN).is_nan());
        assert_eq!(round_bf16(f32::INFINITY), f32::INFINITY);
        assert_eq!(round_bf16(f32::NEG_INFINITY), f32::NEG_INFINITY);
    }

    #[test]
    fn roundtrip_matrix_halves_storage() {
        let mut rng = Pcg64::seed(1);
        let m = Matrix::randn(13, 17, 1.0, &mut rng);
        let b = Bf16Matrix::from_f32(&m);
        assert_eq!(b.bytes() * 2, m.bytes());
        let back = b.to_f32();
        assert!(m.max_abs_diff(&back) < 0.02);
    }

    #[test]
    fn bf16_matmul_close_to_f32() {
        let mut rng = Pcg64::seed(2);
        let a = Matrix::randn(20, 30, 1.0, &mut rng);
        let b = Matrix::randn(30, 10, 1.0, &mut rng);
        let exact = super::super::matmul(&a, &b);
        let approx = matmul_bf16(&Bf16Matrix::from_f32(&a), &Bf16Matrix::from_f32(&b));
        let scale = exact.abs_max().max(1.0);
        assert!(exact.max_abs_diff(&approx) / scale < 0.05);
    }
}
