//! Deterministic parallel execution engine.
//!
//! The paper's headline is rank-independent *runtime*; this module makes
//! the step loop scale with cores instead of layer count while keeping
//! every result **bit-identical to sequential execution for any thread
//! count** (property-tested in `tests/parallel_determinism.rs`). Three
//! rules make that possible, and every user of this module follows them:
//!
//! 1. **Index-deterministic work.** Work is split into indexed chunks whose
//!    outputs depend only on the chunk index, never on which thread ran
//!    them or in what order. Row-partitioned kernels keep each output
//!    element's floating-point summation order exactly as the sequential
//!    kernel computes it.
//! 2. **Chunk-bound scratch.** Mutable scratch is bound to the chunk index
//!    ([`ShardedWorkspace`]: shard `k` ↔ chunk `k`), so pooled-buffer reuse
//!    replays identically every step and the PR-1 zero-allocation invariant
//!    holds per shard.
//! 3. **Disjoint writes.** Chunks write disjoint memory (layer ranges, row
//!    ranges, ring-transfer chunks); no reductions across chunks exist on
//!    any hot path.
//!
//! Thread count comes from `FFT_SUBSPACE_THREADS` (else
//! `available_parallelism()`); `FFT_SUBSPACE_THREADS=1` forces the whole
//! stack sequential. Entry points: [`ThreadPool::par_for`] /
//! [`ThreadPool::par_chunks`] (allocation-free), [`ThreadPool::scope`]
//! (convenience), [`par_for_each_mut`] (slice fan-out), and
//! `optim::common::step_layers_parallel` (disjoint-layer stepping).

mod pool;
mod sharded;

pub use pool::{default_threads, global, ThreadPool, Scope, SendPtr};
pub use sharded::{ShardCells, ShardedWorkspace};

/// The one contiguous-partition rule every parallel path uses: split `n`
/// items over at most `lanes` chunks; chunk `k` covers
/// `[k·per, min((k+1)·per, n))`. Returns `(per, n_chunks)`. Centralized so
/// the chunk↔shard binding can never diverge between kernels.
pub fn partition(lanes: usize, n: usize) -> (usize, usize) {
    let t = lanes.min(n).max(1);
    let per = n.div_ceil(t);
    (per, n.div_ceil(per))
}

/// Partition `n_rows` rows of `width` elements over the pool and hand each
/// chunk its disjoint slab of `data` as `body(slab, lo, hi)` (where `slab`
/// is rows `lo..hi`, indexed `(i - lo) * width`). Runs inline sequentially
/// when the pool has one lane or there is one chunk — same bits either way
/// as long as `body` is per-row deterministic.
pub fn par_row_slabs<T: Send>(
    pool: &ThreadPool,
    n_rows: usize,
    width: usize,
    data: &mut [T],
    body: impl Fn(&mut [T], usize, usize) + Sync,
) {
    if n_rows == 0 {
        return;
    }
    debug_assert_eq!(data.len(), n_rows * width);
    let (per, n_chunks) = partition(pool.threads(), n_rows);
    if n_chunks <= 1 {
        body(data, 0, n_rows);
        return;
    }
    let base = SendPtr(data.as_mut_ptr());
    pool.par_chunks(n_chunks, |k| {
        let lo = k * per;
        let hi = (lo + per).min(n_rows);
        // SAFETY: chunk k owns rows [lo, hi) — disjoint across chunks, and
        // `data` outlives the blocking par_chunks call.
        let slab = unsafe {
            std::slice::from_raw_parts_mut(base.0.add(lo * width), (hi - lo) * width)
        };
        body(slab, lo, hi);
    });
}

/// Run `f(i, &mut items[i])` for every element, partitioned across the
/// pool in contiguous index ranges. Deterministic as long as each `f`
/// invocation depends only on `i` and `items[i]`.
pub fn par_for_each_mut<T: Send>(
    pool: &ThreadPool,
    items: &mut [T],
    f: impl Fn(usize, &mut T) + Sync,
) {
    let n = items.len();
    let base = SendPtr(items.as_mut_ptr());
    pool.par_for(n, |i| {
        // SAFETY: par_for hands each index to exactly one thread, and the
        // slice outlives the (blocking) call.
        let item = unsafe { &mut *base.0.add(i) };
        f(i, item);
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_covers_exactly_once() {
        for lanes in [1usize, 3, 8] {
            for n in [1usize, 2, 7, 8, 9, 100] {
                let (per, n_chunks) = partition(lanes, n);
                let mut covered = 0;
                for k in 0..n_chunks {
                    let lo = k * per;
                    let hi = (lo + per).min(n);
                    assert!(lo < hi, "empty chunk lanes={lanes} n={n} k={k}");
                    covered += hi - lo;
                }
                assert_eq!(covered, n, "lanes={lanes} n={n}");
                assert!(n_chunks <= lanes.max(1));
            }
        }
    }

    #[test]
    fn par_row_slabs_writes_every_row_once() {
        let pool = ThreadPool::new(4);
        let (rows, width) = (37usize, 5usize);
        let mut data = vec![0u32; rows * width];
        par_row_slabs(&pool, rows, width, &mut data, |slab, lo, hi| {
            for i in lo..hi {
                for j in 0..width {
                    slab[(i - lo) * width + j] += (i * width + j) as u32 + 1;
                }
            }
        });
        for (k, v) in data.iter().enumerate() {
            assert_eq!(*v, k as u32 + 1);
        }
    }

    #[test]
    fn par_for_each_mut_touches_every_item_once() {
        let pool = ThreadPool::new(4);
        let mut items = vec![0u64; 257];
        par_for_each_mut(&pool, &mut items, |i, v| {
            *v += i as u64 + 1;
        });
        for (i, v) in items.iter().enumerate() {
            assert_eq!(*v, i as u64 + 1);
        }
    }

    #[test]
    fn par_for_each_mut_matches_sequential() {
        let pool1 = ThreadPool::new(1);
        let pool4 = ThreadPool::new(4);
        let work = |i: usize, v: &mut f32| {
            // order-sensitive per element, index-deterministic overall
            for k in 0..=i % 7 {
                *v += (k as f32 + 0.5) * 1e-3;
            }
        };
        let mut a = vec![0.0f32; 100];
        let mut b = vec![0.0f32; 100];
        par_for_each_mut(&pool1, &mut a, work);
        par_for_each_mut(&pool4, &mut b, work);
        assert_eq!(a, b);
    }
}
