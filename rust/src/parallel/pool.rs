//! Dependency-free fork–join thread pool (no rayon/crossbeam offline).
//!
//! Design constraints, in priority order:
//!
//! 1. **Determinism** — work is split into *indexed chunks*; which OS thread
//!    executes a chunk never affects what the chunk computes. Callers bind
//!    data to chunk indices (e.g. workspace shard `k` ↔ chunk `k`), so
//!    results are bit-identical for any thread count, including 1.
//! 2. **Zero allocations at dispatch** — [`ThreadPool::par_chunks`] passes a
//!    stack-held fat pointer to the workers and synchronizes with a
//!    mutex/condvar pair; no job boxing, no queue growth. This keeps the
//!    optimizer hot path inside the counting-allocator proof
//!    (`tests/alloc_steady_state.rs`).
//! 3. **Nested calls degrade gracefully** — a `par_*` call made from inside
//!    a pool task runs inline on the calling thread (same results, no
//!    deadlock), so library code may parallelize unconditionally.
//!
//! The pool spawns `threads − 1` workers; the dispatching thread claims
//! chunks too, so `threads == 1` means "no workers, everything inline".

use std::cell::Cell;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Worker/dispatcher-shared state. `task` is the address of a stack-held
/// [`TaskHeader`] in the dispatching thread; it is only dereferenced by
/// threads that claimed a chunk under the lock, and the dispatcher does not
/// return (so the header does not die) until every claimed chunk finished.
struct PoolState {
    /// Bumped once per `par_chunks` dispatch so parked workers can tell a
    /// fresh batch from the one they already drained.
    epoch: u64,
    /// `&TaskHeader` as `usize`; 0 = no active batch.
    task: usize,
    n_chunks: usize,
    next_chunk: usize,
    /// Chunks claimed but not yet finished.
    active: usize,
    panicked: bool,
    shutdown: bool,
}

struct Inner {
    state: Mutex<PoolState>,
    /// Workers park here waiting for a new epoch.
    work_cv: Condvar,
    /// The dispatcher parks here waiting for `active == 0`.
    done_cv: Condvar,
    /// Serializes dispatchers: the pool runs one batch at a time, so
    /// concurrent `par_chunks` calls from independent threads (parallel
    /// test runners, trainer + optimizer) queue up instead of corrupting
    /// the shared batch state. Workers never dispatch (nested calls run
    /// inline), so this cannot deadlock.
    dispatch_gate: Mutex<()>,
}

/// Lifetime-erased handle to the dispatched closure. Lives on the
/// dispatcher's stack for the duration of one `par_chunks` call.
struct TaskHeader<'a> {
    f: &'a (dyn Fn(usize) + Sync),
}

thread_local! {
    /// True while this thread is executing a pool chunk — nested `par_*`
    /// calls check it and run inline instead of deadlocking the pool.
    static IN_TASK: Cell<bool> = const { Cell::new(false) };
}

fn run_chunk(f: &(dyn Fn(usize) + Sync), k: usize) -> bool {
    IN_TASK.with(|c| c.set(true));
    let ok = catch_unwind(AssertUnwindSafe(|| f(k))).is_ok();
    IN_TASK.with(|c| c.set(false));
    ok
}

fn worker_loop(inner: Arc<Inner>) {
    let mut seen_epoch = 0u64;
    let mut st = inner.state.lock().unwrap();
    loop {
        if st.shutdown {
            return;
        }
        if st.task != 0 && st.epoch != seen_epoch {
            seen_epoch = st.epoch;
            while st.next_chunk < st.n_chunks {
                let k = st.next_chunk;
                st.next_chunk += 1;
                st.active += 1;
                let task = st.task;
                drop(st);
                // SAFETY: the header outlives this deref — we claimed chunk
                // `k` under the lock, so the dispatcher's completion wait
                // cannot pass until we decrement `active` below.
                let f = unsafe { (*(task as *const TaskHeader)).f };
                let ok = run_chunk(f, k);
                st = inner.state.lock().unwrap();
                st.active -= 1;
                if !ok {
                    st.panicked = true;
                }
                if st.next_chunk >= st.n_chunks && st.active == 0 {
                    inner.done_cv.notify_all();
                }
            }
        } else {
            st = inner.work_cv.wait(st).unwrap();
        }
    }
}

/// Scoped fork–join thread pool over indexed chunks. See the module docs
/// for the determinism / allocation / nesting contract.
pub struct ThreadPool {
    inner: Arc<Inner>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
}

impl ThreadPool {
    /// Pool with `threads` total execution lanes (the dispatching thread is
    /// one of them, so `threads − 1` OS workers are spawned). `0` is
    /// clamped to 1.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let inner = Arc::new(Inner {
            state: Mutex::new(PoolState {
                epoch: 0,
                task: 0,
                n_chunks: 0,
                next_chunk: 0,
                active: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            dispatch_gate: Mutex::new(()),
        });
        let handles = (1..threads)
            .map(|i| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("fft-par-{i}"))
                    .spawn(move || worker_loop(inner))
                    .expect("spawning thread-pool worker")
            })
            .collect();
        ThreadPool { inner, handles, threads }
    }

    /// Total execution lanes (≥ 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(k)` for every chunk index `k` in `0..n_chunks`, distributing
    /// chunks across the pool. Blocks until all chunks finished. Inline
    /// (sequential, identical results) when the pool has one lane, there is
    /// one chunk, or the caller is itself a pool task. Allocation-free.
    ///
    /// Chunks must touch disjoint data (or synchronize internally); the
    /// execution *order* of chunks is unspecified, so determinism requires
    /// per-chunk outputs to depend only on the chunk index.
    pub fn par_chunks(&self, n_chunks: usize, f: impl Fn(usize) + Sync) {
        if n_chunks == 0 {
            return;
        }
        if self.threads <= 1 || n_chunks == 1 || IN_TASK.with(|c| c.get()) {
            for k in 0..n_chunks {
                f(k);
            }
            return;
        }
        self.dispatch(n_chunks, &f);
    }

    fn dispatch(&self, n_chunks: usize, f: &(dyn Fn(usize) + Sync)) {
        let header = TaskHeader { f };
        let inner = &*self.inner;
        // One batch at a time; a panicking earlier dispatcher poisons the
        // gate but leaves the batch state clean (cleanup precedes panic).
        let _gate = inner
            .dispatch_gate
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        let mut st = inner.state.lock().unwrap();
        debug_assert_eq!(st.task, 0, "ThreadPool::dispatch re-entered");
        st.epoch = st.epoch.wrapping_add(1);
        st.task = &header as *const TaskHeader as usize;
        st.n_chunks = n_chunks;
        st.next_chunk = 0;
        st.panicked = false;
        inner.work_cv.notify_all();
        // The dispatcher claims chunks alongside the workers.
        while st.next_chunk < st.n_chunks {
            let k = st.next_chunk;
            st.next_chunk += 1;
            st.active += 1;
            drop(st);
            let ok = run_chunk(f, k);
            st = inner.state.lock().unwrap();
            st.active -= 1;
            if !ok {
                st.panicked = true;
            }
        }
        while st.active > 0 {
            st = inner.done_cv.wait(st).unwrap();
        }
        st.task = 0;
        let panicked = st.panicked;
        drop(st);
        if panicked {
            panic!("ThreadPool: a parallel chunk panicked");
        }
    }

    /// Run `f(i)` for every `i in 0..n`, partitioned into at most
    /// [`ThreadPool::threads`] contiguous index ranges (chunk `k` covers
    /// `[k·⌈n/t⌉, (k+1)·⌈n/t⌉)`). Same contract as [`ThreadPool::par_chunks`].
    pub fn par_for(&self, n: usize, f: impl Fn(usize) + Sync) {
        if n == 0 {
            return;
        }
        let t = self.threads.min(n);
        let per = n.div_ceil(t);
        let n_chunks = n.div_ceil(per);
        self.par_chunks(n_chunks, |k| {
            let lo = k * per;
            let hi = (lo + per).min(n);
            for i in lo..hi {
                f(i);
            }
        });
    }

    /// Fork a set of heterogeneous jobs and join them all (convenience API;
    /// boxes each job, so **not** for allocation-free hot paths — those use
    /// `par_chunks`/`par_for`). Jobs may borrow from the enclosing scope.
    pub fn scope<'env>(&self, build: impl FnOnce(&Scope<'env>)) {
        let scope = Scope { jobs: std::cell::RefCell::new(Vec::new()) };
        build(&scope);
        let jobs = scope.jobs.into_inner();
        if jobs.is_empty() {
            return;
        }
        let slots: Vec<Mutex<Option<Job<'env>>>> =
            jobs.into_iter().map(|j| Mutex::new(Some(j))).collect();
        self.par_chunks(slots.len(), |k| {
            if let Some(job) = slots[k].lock().unwrap().take() {
                job();
            }
        });
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
            self.inner.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

type Job<'env> = Box<dyn FnOnce() + Send + 'env>;

/// Collects jobs for [`ThreadPool::scope`]; all spawned jobs run (possibly
/// in parallel, in unspecified order) when the builder closure returns.
pub struct Scope<'env> {
    jobs: std::cell::RefCell<Vec<Job<'env>>>,
}

impl<'env> Scope<'env> {
    pub fn spawn(&self, f: impl FnOnce() + Send + 'env) {
        self.jobs.borrow_mut().push(Box::new(f));
    }
}

/// Thread count the process-global pool uses: the `FFT_SUBSPACE_THREADS`
/// environment variable when set to a positive integer, otherwise
/// [`std::thread::available_parallelism`].
pub fn default_threads() -> usize {
    match std::env::var("FFT_SUBSPACE_THREADS") {
        Ok(v) => match v.trim().parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => hardware_threads(),
        },
        Err(_) => hardware_threads(),
    }
}

fn hardware_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

static GLOBAL: OnceLock<Arc<ThreadPool>> = OnceLock::new();

/// Process-global pool, built lazily with [`default_threads`] lanes. All
/// optimizers and the trainer share it unless explicitly configured with a
/// private pool (`OptimizerConfig::threads`).
pub fn global() -> Arc<ThreadPool> {
    GLOBAL
        .get_or_init(|| Arc::new(ThreadPool::new(default_threads())))
        .clone()
}

/// Raw-pointer wrapper that asserts cross-thread transferability. Used to
/// hand each chunk a disjoint region of a caller-owned buffer.
///
/// # Safety contract (caller's burden)
/// Every dereference must target memory that (a) outlives the parallel
/// call and (b) is accessed by at most one chunk — the standard
/// "disjoint row ranges" argument of the `_on` kernels.
pub struct SendPtr<T>(pub *mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        SendPtr(self.0)
    }
}
impl<T> Copy for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn par_for_visits_every_index_once() {
        let pool = ThreadPool::new(4);
        let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        pool.par_for(100, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_chunks_single_thread_is_inline() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.threads(), 1);
        let sum = AtomicUsize::new(0);
        pool.par_chunks(10, |k| {
            sum.fetch_add(k, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn nested_par_for_runs_inline_without_deadlock() {
        let pool = ThreadPool::new(3);
        let total = AtomicUsize::new(0);
        pool.par_for(6, |_| {
            // nested call from inside a task: must inline, not deadlock
            pool.par_for(5, |j| {
                total.fetch_add(j + 1, Ordering::Relaxed);
            });
        });
        assert_eq!(total.load(Ordering::Relaxed), 6 * 15);
    }

    #[test]
    fn pool_reusable_across_many_batches() {
        let pool = ThreadPool::new(4);
        for round in 0..50 {
            let sum = AtomicUsize::new(0);
            pool.par_for(round + 1, |i| {
                sum.fetch_add(i, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), round * (round + 1) / 2);
        }
    }

    #[test]
    fn concurrent_dispatchers_serialize_cleanly() {
        // Several independent threads dispatching onto ONE pool (the
        // global-pool situation under parallel test runners / trainer +
        // optimizer): batches must serialize, never corrupt each other.
        let pool = Arc::new(ThreadPool::new(3));
        let total = Arc::new(AtomicUsize::new(0));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let pool = Arc::clone(&pool);
                let total = Arc::clone(&total);
                std::thread::spawn(move || {
                    for _ in 0..25 {
                        pool.par_for(10, |i| {
                            total.fetch_add(i + 1, Ordering::Relaxed);
                        });
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(total.load(Ordering::Relaxed), 4 * 25 * 55);
    }

    #[test]
    fn panic_in_chunk_propagates_and_pool_survives() {
        let pool = ThreadPool::new(3);
        let res = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.par_chunks(8, |k| {
                if k == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(res.is_err());
        // the pool still works after a panicked batch
        let sum = AtomicUsize::new(0);
        pool.par_for(4, |i| {
            sum.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn scope_runs_all_jobs() {
        let pool = ThreadPool::new(3);
        let a = AtomicUsize::new(0);
        let b = AtomicUsize::new(0);
        pool.scope(|s| {
            s.spawn(|| a.store(7, Ordering::Relaxed));
            s.spawn(|| b.store(9, Ordering::Relaxed));
        });
        assert_eq!(a.load(Ordering::Relaxed), 7);
        assert_eq!(b.load(Ordering::Relaxed), 9);
    }

    #[test]
    fn default_threads_is_positive() {
        assert!(default_threads() >= 1);
        assert!(global().threads() >= 1);
    }
}
