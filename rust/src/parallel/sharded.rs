//! Per-chunk workspace shards for parallel layer stepping.
//!
//! The PR-1 zero-allocation invariant (ROADMAP §Hot-path architecture) is
//! per-[`Workspace`]: a pool stays warm only if the same request pattern
//! replays against the same pool every step. Under parallel stepping the
//! binding is therefore **chunk → shard**, not thread → shard: chunk `k` of
//! a `par_chunks` dispatch always uses shard `k`, so whichever OS thread
//! picks the chunk up, the shard sees the same take/give sequence every
//! step and stops allocating after warmup.

use std::cell::UnsafeCell;

use crate::tensor::Workspace;

use super::ThreadPool;

/// A fixed set of independent [`Workspace`]s, one per parallel chunk.
pub struct ShardedWorkspace {
    shards: Vec<Workspace>,
}

impl ShardedWorkspace {
    /// `n` independent shards (clamped to ≥ 1).
    pub fn new(n: usize) -> Self {
        ShardedWorkspace {
            shards: (0..n.max(1)).map(|_| Workspace::new()).collect(),
        }
    }

    /// One shard per pool lane — the sizing every optimizer uses.
    pub fn for_pool(pool: &ThreadPool) -> Self {
        ShardedWorkspace::new(pool.threads())
    }

    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        false // ≥ 1 by construction
    }

    /// Direct access to one shard (sequential call sites use shard 0).
    pub fn shard_mut(&mut self, k: usize) -> &mut Workspace {
        &mut self.shards[k]
    }

    /// Chunk-indexed view for parallel dispatch; see [`ShardCells::shard`].
    pub fn cells(&mut self) -> ShardCells<'_> {
        // SAFETY of the cast: `UnsafeCell<T>` is `repr(transparent)` over
        // `T`, so a `[Workspace]` and a `[UnsafeCell<Workspace>]` have the
        // same layout; we hold `&mut self`, so handing out interior-mutable
        // views is sound as long as indices stay disjoint (ShardCells'
        // contract).
        let slice: *mut [Workspace] = self.shards.as_mut_slice();
        ShardCells {
            cells: unsafe { &*(slice as *const [UnsafeCell<Workspace>]) },
        }
    }
}

/// Borrowed, `Sync` view of the shards that lets each parallel chunk take
/// `&mut` access to *its own* shard by index.
pub struct ShardCells<'a> {
    cells: &'a [UnsafeCell<Workspace>],
}

// SAFETY: the only access path is `shard`, whose contract requires callers
// to use disjoint indices across threads.
unsafe impl Sync for ShardCells<'_> {}

impl ShardCells<'_> {
    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// Exclusive access to shard `k`.
    ///
    /// # Safety
    /// Each index must be live in at most one thread at a time. The
    /// `par_chunks` pattern (chunk `k` is claimed by exactly one thread,
    /// chunk `k` uses only shard `k`) satisfies this by construction.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn shard(&self, k: usize) -> &mut Workspace {
        &mut *self.cells[k].get()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_are_independent_pools() {
        let mut sw = ShardedWorkspace::new(3);
        assert_eq!(sw.len(), 3);
        let m = sw.shard_mut(0).take(4, 4);
        sw.shard_mut(0).give(m);
        assert_eq!(sw.shard_mut(0).pooled_f32_buffers(), 1);
        assert_eq!(sw.shard_mut(1).pooled_f32_buffers(), 0);
    }

    #[test]
    fn zero_shards_clamps_to_one() {
        let sw = ShardedWorkspace::new(0);
        assert_eq!(sw.len(), 1);
    }

    #[test]
    fn cells_give_disjoint_mut_access() {
        let pool = ThreadPool::new(3);
        let mut sw = ShardedWorkspace::for_pool(&pool);
        let n = sw.len();
        let cells = sw.cells();
        pool.par_chunks(n, |k| {
            // SAFETY: chunk k touches only shard k
            let ws = unsafe { cells.shard(k) };
            let m = ws.take(2 + k, 2);
            ws.give(m);
        });
        for k in 0..n {
            assert_eq!(sw.shard_mut(k).pooled_f32_buffers(), 1, "shard {k}");
        }
    }
}
