//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network and no vendored registry, so the
//! workspace ships this minimal shim instead: an opaque string-chain error
//! type plus the `anyhow!` / `bail!` / `ensure!` macros and the `Context`
//! extension trait — exactly the surface the crate uses. Like the real
//! `anyhow::Error`, [`Error`] deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion coherent.

use std::fmt;

/// Opaque error: a message plus the chain of contexts wrapped around it.
pub struct Error {
    /// Outermost context first; the root cause is last.
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message (mirror of `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn to_string_outer(&self) -> &str {
        &self.chain[0]
    }

    /// Iterate the context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{e}` prints the outermost message; `{e:#}` prints the full chain
        // (same convention as the real anyhow).
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result` with the usual defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T, E>: Sized {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static;

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: Into<Error>,
{
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T, core::convert::Infallible> for Option<T> {
    fn context<C>(self, context: C) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
    {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from format args.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Early-return an `Err(anyhow!(..))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Bail unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn context_chains_and_displays() {
        let e: Result<()> = Err(io_err());
        let e = e.context("reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert!(format!("{e:#}").contains("missing"));
        assert!(format!("{e:?}").contains("Caused by"));
    }

    #[test]
    fn macros_work() {
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(f(true).unwrap(), 7);
        assert!(f(false).unwrap_err().to_string().contains("false"));
        let e = anyhow!("code {}", 404);
        assert_eq!(e.to_string(), "code 404");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(e.to_string(), "empty");
    }
}
