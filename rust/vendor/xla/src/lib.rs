//! Offline stub of the `xla` (xla-rs / PJRT) bindings.
//!
//! The PJRT C-API plugin and its Rust bindings are not available in this
//! build environment, so this crate provides the exact API surface
//! `runtime/client.rs` consumes with constructors that fail cleanly at
//! *runtime*: `PjRtClient::cpu()` returns an error, every artifact-backed
//! test skips, and the pure-rust 95% of the crate (tensor / fft /
//! projection / optim / coordinator / experiments math) builds and tests
//! normally. Swapping the real bindings back in is a one-line change in
//! `Cargo.toml`.

use std::fmt;
use std::path::Path;

/// Error type for every fallible stub operation. Implements
/// `std::error::Error` so it converts into `anyhow::Error` via `?`.
#[derive(Debug)]
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable(what: &str) -> Error {
        Error {
            msg: format!(
                "{what}: PJRT/XLA bindings are stubbed out in this offline \
                 build (rust/vendor/xla); install the real `xla` crate and \
                 its PJRT CPU plugin to execute AOT artifacts"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Host literal (stub).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable("Literal::reshape"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

impl From<f32> for Literal {
    fn from(_v: f32) -> Literal {
        Literal { _private: () }
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Loaded executable (stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_reports_stub() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stubbed"));
    }

    #[test]
    fn error_converts_to_std_error_trait_object() {
        let err: Box<dyn std::error::Error> = Box::new(Error::unavailable("x"));
        assert!(err.to_string().contains("x"));
    }
}
