//! Fault-tolerance contract: a run that hits an injected numerical fault
//! must end **bit-identical** to the matching fault-free trajectory.
//!
//! Three recovery paths, each driven by the deterministic `train::fault`
//! injector (ROADMAP §Fault tolerance):
//!
//! * `guard=skip` — a poisoned step is dropped without touching optimizer
//!   state, so the run equals a reference that simply omits that step's
//!   update (all six engine presets × every state dtype).
//! * `guard=rollback` — after a trip the run restores the latest retained
//!   rotation snapshot (PR-5 restore into a **fresh** optimizer) and
//!   replays; both the crash-restart shape and the in-process rollback
//!   shape converge to the uninterrupted run's bits.
//! * worker-lane retry — an injected lane panic is absorbed by the
//!   bounded `WorkerSet` retry; a persistent failure still propagates.
//!
//! Everything is seeded: the injector picks its poisoned element from its
//! own RNG stream, fires exactly once, and the tests replay byte-for-byte
//! on every run (`make test-faults`).

use std::sync::Arc;

use fft_subspace::coordinator::WorkerSet;
use fft_subspace::optim::{
    build_optimizer, LayerMeta, Optimizer, OptimizerConfig, OptimizerKind, ParamKind,
};
use fft_subspace::parallel::ThreadPool;
use fft_subspace::projection::{ProjectionKind, RankNorm, SharedDct};
use fft_subspace::tensor::{Matrix, StateDtype};
use fft_subspace::train::checkpoint::{self, CheckpointRotation, TrainState};
use fft_subspace::train::{FaultInjector, FaultPlan, GuardPolicy, StepGuard};
use fft_subspace::util::Pcg64;

/// Same mixed layer zoo as `tests/resume_determinism.rs`.
fn layer_zoo() -> Vec<LayerMeta> {
    vec![
        LayerMeta::new("wq", 48, 32, ParamKind::Linear),
        LayerMeta::new("w_gate", 32, 48, ParamKind::Linear),
        LayerMeta::new("wk", 40, 24, ParamKind::Linear),
        LayerMeta::new("wv", 32, 32, ParamKind::Linear),
        LayerMeta::new("norm", 1, 32, ParamKind::Norm),
        LayerMeta::new("embed", 64, 32, ParamKind::Embed),
    ]
}

fn grad_seq(metas: &[LayerMeta], steps: usize, seed: u64) -> Vec<Vec<Matrix>> {
    let mut rng = Pcg64::seed(seed);
    (0..steps)
        .map(|_| {
            metas
                .iter()
                .map(|m| Matrix::randn(m.rows, m.cols, 0.1, &mut rng))
                .collect()
        })
        .collect()
}

fn bits(params: &[Matrix]) -> Vec<Vec<u32>> {
    params
        .iter()
        .map(|p| p.data.iter().map(|v| v.to_bits()).collect())
        .collect()
}

fn decaying_lr(step: usize) -> f32 {
    1e-2 / (1.0 + step as f32 * 0.1)
}

fn cfg_for(state_dtype: StateDtype) -> OptimizerConfig {
    OptimizerConfig {
        rank: 8,
        threads: Some(1),
        update_interval: 3,
        state_dtype,
        ..Default::default()
    }
}

fn zero_params(metas: &[LayerMeta]) -> Vec<Matrix> {
    metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect()
}

/// Synthetic finite per-step loss for the guard (spike detection off).
fn fake_loss(step: usize) -> f64 {
    1.0 + step as f64 * 0.01
}

const SIX_PRESETS: [OptimizerKind; 6] = [
    OptimizerKind::DctAdamW,
    OptimizerKind::Trion,
    OptimizerKind::GaLore,
    OptimizerKind::Fira,
    OptimizerKind::Frugal,
    OptimizerKind::LdAdamW,
];

/// Fresh per-test scratch directory (process id keeps concurrent cargo
/// invocations apart).
fn scratch_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("fft_subspace_fault_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// `guard=skip` contract: with the injector poisoning step `k`'s gradient,
/// the guarded run's params AND state blob equal a reference run that
/// omits step `k`'s update entirely — skipping must not touch moments,
/// step counters, subspace RNG streams, or error-feedback residuals.
fn assert_skip_matches_omitted_step(
    kind: &OptimizerKind,
    state_dtype: StateDtype,
    plan: FaultPlan,
) {
    let metas = layer_zoo();
    let n = 10usize;
    let k = plan.grad_step.expect("plan must poison a gradient step");
    assert!(k < n, "fault step {k} outside run of {n} steps");
    let grads = grad_seq(&metas, n, 42);
    let cfg = cfg_for(state_dtype);

    // reference: the same run with step k's update omitted
    let mut ref_opt = build_optimizer(kind, &metas, &cfg);
    let mut ref_params = zero_params(&metas);
    for (step, g) in grads.iter().enumerate() {
        if step == k {
            continue;
        }
        ref_opt.step(&mut ref_params, g, decaying_lr(step));
    }

    // guarded run: injector poisons step k, StepGuard(skip) drops it
    let injector = FaultInjector::new(plan);
    let mut guard = StepGuard::new(GuardPolicy::Skip, 0.0);
    let mut opt = build_optimizer(kind, &metas, &cfg);
    let mut params = zero_params(&metas);
    let mut skipped = Vec::new();
    for (step, g) in grads.iter().enumerate() {
        let mut g = g.clone();
        injector.corrupt_grads(step, &mut g);
        let verdict = guard.check(fake_loss(step), &g);
        if !verdict.is_healthy() {
            assert_eq!(verdict.reason(), "non-finite-grad");
            skipped.push(step);
            continue;
        }
        opt.step(&mut params, &g, decaying_lr(step));
    }
    assert_eq!(skipped, vec![k], "{}: guard tripped on the wrong steps", kind.name());

    assert_eq!(
        bits(&ref_params),
        bits(&params),
        "{} (state-dtype={}): skip-guarded run diverged from omitted-step reference",
        kind.name(),
        state_dtype.name()
    );
    assert_eq!(
        ref_opt.save_state().unwrap(),
        opt.save_state().unwrap(),
        "{} (state-dtype={}): optimizer state blobs differ after skip",
        kind.name(),
        state_dtype.name()
    );
}

fn nan_at_4() -> FaultPlan {
    FaultPlan::parse("grad-nan@4").unwrap()
}

#[test]
fn guard_skip_matches_omitted_step_f32() {
    for kind in &SIX_PRESETS {
        assert_skip_matches_omitted_step(kind, StateDtype::F32, nan_at_4());
    }
}

#[test]
fn guard_skip_matches_omitted_step_bf16() {
    for kind in &SIX_PRESETS {
        assert_skip_matches_omitted_step(kind, StateDtype::Bf16, nan_at_4());
    }
}

#[test]
fn guard_skip_matches_omitted_step_q8() {
    for kind in &SIX_PRESETS {
        assert_skip_matches_omitted_step(kind, StateDtype::Q8, nan_at_4());
    }
}

#[test]
fn guard_skip_handles_inf_and_fixed_layer() {
    // +Inf poison pinned to a specific layer (grammar's `.LAYER` form)
    let plan = FaultPlan::parse("grad-inf@4.2, seed@9").unwrap();
    assert_skip_matches_omitted_step(&OptimizerKind::DctAdamW, StateDtype::F32, plan);
}

#[test]
fn env_selected_fault_recovers() {
    // `make test-matrix` sweeps FFT_SUBSPACE_FAULT over gradient faults;
    // default to a fixed NaN plan so the test always exercises the path.
    let plan = FaultPlan::from_env().expect("FFT_SUBSPACE_FAULT parses");
    let plan = if plan.grad_step.is_some() {
        plan
    } else {
        FaultPlan::parse("grad-nan@3").unwrap()
    };
    let mut plan = plan;
    // keep the poisoned step inside the 10-step run regardless of the env
    if plan.grad_step.unwrap() >= 10 {
        plan.grad_step = Some(3);
    }
    // this harness exercises the gradient path only — a tear directive
    // would race the dedicated torn-write test's global latch
    plan.tear_at = None;
    assert_skip_matches_omitted_step(&OptimizerKind::DctAdamW, StateDtype::F32, plan);
}

/// `guard=rollback`, crash-restart shape: run until the guard trips, lose
/// the process, restart from the newest retained snapshot with a FRESH
/// optimizer, and finish with a clean (transient-fault) replay. Final
/// params must equal the uninterrupted run's to the bit.
#[test]
fn rollback_crash_restart_matches_uninterrupted() {
    let metas = layer_zoo();
    let (n, k, interval) = (12usize, 7usize, 3usize);
    let grads = grad_seq(&metas, n, 42);
    let cfg = cfg_for(StateDtype::F32);
    for kind in &SIX_PRESETS {
        // uninterrupted reference
        let mut ref_opt = build_optimizer(kind, &metas, &cfg);
        let mut ref_params = zero_params(&metas);
        for (step, g) in grads.iter().enumerate() {
            ref_opt.step(&mut ref_params, g, decaying_lr(step));
        }

        let dir = scratch_dir(&format!("crash_{}", kind.name()));
        let rot = CheckpointRotation::new(&dir, 2);

        // phase 1: run with snapshots every `interval` steps; crash at the trip
        let injector = FaultInjector::new(FaultPlan::parse(&format!("grad-nan@{k}")).unwrap());
        let mut guard = StepGuard::new(GuardPolicy::Rollback, 0.0);
        let mut opt = build_optimizer(kind, &metas, &cfg);
        let mut params = zero_params(&metas);
        let mut tripped_at = None;
        for (step, g) in grads.iter().enumerate() {
            let mut g = g.clone();
            injector.corrupt_grads(step, &mut g);
            if !guard.check(fake_loss(step), &g).is_healthy() {
                tripped_at = Some(step);
                break; // "crash": optimizer and params are simply lost
            }
            opt.step(&mut params, &g, decaying_lr(step));
            let completed = step + 1;
            if completed % interval == 0 {
                let state = TrainState {
                    step: completed as u64,
                    optimizer: opt.name().to_string(),
                    opt_state: opt.save_state().unwrap(),
                    sync: Vec::new(),
                };
                rot.save(completed as u64, &params, &state).unwrap();
            }
        }
        assert_eq!(tripped_at, Some(k), "{}", kind.name());
        drop(opt);

        // phase 2: restart — newest retained snapshot, fresh optimizer,
        // clean replay (the transient fault does not recur)
        let (snap_step, path) = rot
            .latest()
            .unwrap()
            .expect("a snapshot was retained before the crash");
        assert_eq!(snap_step, 6, "{}: wrong restore point", kind.name());
        let ck = checkpoint::load_full(&path).unwrap();
        let state = ck.state.expect("v2 snapshot carries optimizer state");
        assert_eq!(state.step as usize, snap_step as usize);
        let mut opt = build_optimizer(kind, &metas, &cfg);
        opt.load_state(&state.opt_state)
            .unwrap_or_else(|e| panic!("{} restore failed: {e:#}", kind.name()));
        let mut params = ck.params;
        for (step, g) in grads.iter().enumerate().skip(snap_step as usize) {
            opt.step(&mut params, g, decaying_lr(step));
        }

        assert_eq!(
            bits(&ref_params),
            bits(&params),
            "{}: crash-restart trajectory diverged from uninterrupted run",
            kind.name()
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// Crash-restart across step-plan modes: the run crashes under the fused
/// shape-batched plan, the restart engine is built `interpreted` (e.g. an
/// operator flips `FFT_SUBSPACE_STEP_PLAN` while diagnosing the fault) —
/// and the trajectory still lands on the uninterrupted fused run's bits.
/// Works because plans are derived state outside the checkpoint
/// fingerprint, and the two modes are bit-identical step for step.
#[test]
fn rollback_restores_across_step_plan_modes() {
    use fft_subspace::optim::StepPlanMode;
    let metas = layer_zoo();
    let (n, k, interval) = (12usize, 7usize, 3usize);
    let grads = grad_seq(&metas, n, 42);
    let fused = OptimizerConfig {
        step_plan: StepPlanMode::Fused,
        ..cfg_for(StateDtype::Q8)
    };
    let interp = OptimizerConfig {
        step_plan: StepPlanMode::Interpreted,
        ..cfg_for(StateDtype::Q8)
    };
    let kind = OptimizerKind::DctAdamW;

    let mut ref_opt = build_optimizer(&kind, &metas, &fused);
    let mut ref_params = zero_params(&metas);
    for (step, g) in grads.iter().enumerate() {
        ref_opt.step(&mut ref_params, g, decaying_lr(step));
    }

    let dir = scratch_dir("crossmode");
    let rot = CheckpointRotation::new(&dir, 2);
    let injector = FaultInjector::new(FaultPlan::parse(&format!("grad-nan@{k}")).unwrap());
    let mut guard = StepGuard::new(GuardPolicy::Rollback, 0.0);
    let mut opt = build_optimizer(&kind, &metas, &fused);
    let mut params = zero_params(&metas);
    for (step, g) in grads.iter().enumerate() {
        let mut g = g.clone();
        injector.corrupt_grads(step, &mut g);
        if !guard.check(fake_loss(step), &g).is_healthy() {
            break;
        }
        opt.step(&mut params, &g, decaying_lr(step));
        let completed = step + 1;
        if completed % interval == 0 {
            let state = TrainState {
                step: completed as u64,
                optimizer: opt.name().to_string(),
                opt_state: opt.save_state().unwrap(),
                sync: Vec::new(),
            };
            rot.save(completed as u64, &params, &state).unwrap();
        }
    }
    drop(opt);

    let (snap_step, path) = rot.latest().unwrap().expect("snapshot retained");
    let ck = checkpoint::load_full(&path).unwrap();
    let state = ck.state.expect("v2 snapshot carries optimizer state");
    let mut opt = build_optimizer(&kind, &metas, &interp);
    opt.load_state(&state.opt_state)
        .expect("fused-mode blob restores into an interpreted engine");
    let mut params = ck.params;
    for (step, g) in grads.iter().enumerate().skip(snap_step as usize) {
        opt.step(&mut params, g, decaying_lr(step));
    }
    assert_eq!(
        bits(&ref_params),
        bits(&params),
        "interpreted restart diverged from the uninterrupted fused run"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// `guard=rollback`, in-process shape (the trainer's actual loop): same
/// one-shot injector, restore + replay inside the run. Because the fault
/// fires exactly once, the replay crosses step `k` cleanly and the run
/// converges to the fault-free bits.
#[test]
fn in_process_rollback_with_one_shot_fault_converges() {
    let metas = layer_zoo();
    let (n, k, interval) = (12usize, 7usize, 3usize);
    let grads = grad_seq(&metas, n, 42);
    let cfg = cfg_for(StateDtype::F32);
    let kind = OptimizerKind::DctAdamW;

    let mut ref_opt = build_optimizer(&kind, &metas, &cfg);
    let mut ref_params = zero_params(&metas);
    for (step, g) in grads.iter().enumerate() {
        ref_opt.step(&mut ref_params, g, decaying_lr(step));
    }

    let dir = scratch_dir("inproc");
    let rot = CheckpointRotation::new(&dir, 2);
    let injector = FaultInjector::new(FaultPlan::parse(&format!("grad-inf@{k}")).unwrap());
    let mut guard = StepGuard::new(GuardPolicy::Rollback, 0.0);
    let mut opt = build_optimizer(&kind, &metas, &cfg);
    let mut params = zero_params(&metas);
    // initial snapshot so a trip before the first periodic save can restore
    rot.save(
        0,
        &params,
        &TrainState {
            step: 0,
            optimizer: opt.name().to_string(),
            opt_state: opt.save_state().unwrap(),
            sync: Vec::new(),
        },
    )
    .unwrap();
    let mut rollbacks = 0usize;
    let mut step = 0usize;
    while step < n {
        let mut g = grads[step].clone();
        injector.corrupt_grads(step, &mut g);
        if !guard.check(fake_loss(step), &g).is_healthy() {
            rollbacks += 1;
            assert!(rollbacks <= 2, "rollback did not converge");
            let (snap_step, path) = rot.latest().unwrap().expect("snapshot retained");
            let ck = checkpoint::load_full(&path).unwrap();
            let state = ck.state.unwrap();
            let mut fresh = build_optimizer(&kind, &metas, &cfg);
            fresh.load_state(&state.opt_state).unwrap();
            opt = fresh;
            params = ck.params;
            guard.reset();
            step = snap_step as usize;
            continue;
        }
        opt.step(&mut params, &g, decaying_lr(step));
        let completed = step + 1;
        if completed % interval == 0 {
            let state = TrainState {
                step: completed as u64,
                optimizer: opt.name().to_string(),
                opt_state: opt.save_state().unwrap(),
                sync: Vec::new(),
            };
            rot.save(completed as u64, &params, &state).unwrap();
        }
        step += 1;
    }
    assert_eq!(rollbacks, 1, "the one-shot fault must trip exactly once");
    assert_eq!(
        bits(&ref_params),
        bits(&params),
        "in-process rollback diverged from fault-free run"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Torn snapshot write: the armed tear fails the save mid-file, the
/// previous snapshot stays loadable, and the retry (latch is one-shot)
/// succeeds. The only test in this binary touching the global tear latch.
#[test]
fn torn_snapshot_write_keeps_previous_and_retry_succeeds() {
    let metas = layer_zoo();
    let grads = grad_seq(&metas, 4, 5);
    let cfg = cfg_for(StateDtype::F32);
    let mut opt = build_optimizer(&OptimizerKind::Frugal, &metas, &cfg);
    let mut params = zero_params(&metas);
    for (step, g) in grads.iter().enumerate() {
        opt.step(&mut params, g, decaying_lr(step));
    }
    let state = |s: u64, opt: &dyn Optimizer| TrainState {
        step: s,
        optimizer: opt.name().to_string(),
        opt_state: opt.save_state().unwrap(),
        sync: Vec::new(),
    };

    let dir = scratch_dir("tear");
    let rot = CheckpointRotation::new(&dir, 3);
    rot.save(3, &params, &state(3, opt.as_ref())).unwrap();

    // arm through the injector (config/env `ckpt-tear@64` path)
    let injector = FaultInjector::new(FaultPlan::parse("ckpt-tear@64").unwrap());
    injector.arm_checkpoint_tear();
    let err = rot.save(6, &params, &state(6, opt.as_ref())).unwrap_err();
    assert!(err.to_string().contains("torn"), "unexpected error: {err:#}");

    // the torn write is invisible to recovery: latest is still step 3
    let (step, path) = rot.latest().unwrap().unwrap();
    assert_eq!(step, 3);
    let ck = checkpoint::load_full(&path).unwrap();
    assert_eq!(bits(&ck.params), bits(&params));

    // latch disarmed by the failed write → the retried save lands
    rot.save(6, &params, &state(6, opt.as_ref())).unwrap();
    assert_eq!(rot.latest().unwrap().unwrap().0, 6);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Injected worker-lane panic: the bounded `WorkerSet` retry absorbs a
/// one-shot lane failure (results match the fault-free run), while a lane
/// that fails every attempt still propagates its panic.
#[test]
fn worker_lane_fault_retries_and_persistent_failure_propagates() {
    let pool = Arc::new(ThreadPool::new(2));
    let ws = WorkerSet::new(4, Arc::clone(&pool));
    let injector = FaultInjector::new(FaultPlan::parse("worker-fail@2.1").unwrap());

    let lane_value = |step: usize, w: usize| ((step + 1) * 100 + w) as u64;
    for step in 0..4 {
        let got = ws.run(|w| {
            // fires before any per-lane state mutates — retry replays cleanly
            injector.maybe_fail_worker(step, w);
            lane_value(step, w)
        });
        let want: Vec<u64> = (0..4).map(|w| lane_value(step, w)).collect();
        assert_eq!(got, want, "step {step}");
    }

    // persistent failure: exhausts MAX_ATTEMPTS and propagates
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        ws.run(|w| {
            if w == 3 {
                panic!("persistent lane failure");
            }
            w
        })
    }));
    assert!(res.is_err(), "a lane failing every attempt must propagate");

    // the pool and worker set survive the panicked batch
    let got = ws.run(|w| w * 2);
    assert_eq!(got, vec![0, 2, 4, 6]);
}

/// Graceful refresh degradation: every projection family keeps its
/// previous basis (bit-for-bit) when handed a non-finite gradient, instead
/// of re-ranking columns / re-orthogonalizing on NaN values.
#[test]
fn projections_retain_basis_on_non_finite_refresh() {
    let (rows, cols, rank) = (16usize, 32usize, 8usize);
    let shared = Arc::new(SharedDct::new(cols));
    let kinds = [
        ProjectionKind::Dct { norm: RankNorm::L2, use_makhoul: true },
        ProjectionKind::Svd,
        ProjectionKind::BlockPower { iters: 2 },
        ProjectionKind::Random,
        ProjectionKind::RandPerm,
    ];
    for kind in &kinds {
        let mut proj = kind.build(cols, rank, Some(Arc::clone(&shared)), 11);
        let mut rng = Pcg64::seed(7);
        let g_warm = Matrix::randn(rows, cols, 1.0, &mut rng);
        let _ = proj.refresh_and_project(&g_warm);
        let basis_before = proj.basis();

        let mut g_bad = Matrix::randn(rows, cols, 1.0, &mut rng);
        g_bad.data[5] = f32::NAN;
        let _ = proj.refresh_and_project(&g_bad);
        let basis_after = proj.basis();
        assert_eq!(
            bits(std::slice::from_ref(&basis_before)),
            bits(std::slice::from_ref(&basis_after)),
            "{}: basis changed on non-finite refresh",
            kind.name()
        );

        // a healthy refresh afterwards updates the basis again (the gate
        // defers, it doesn't wedge) — except RandPerm, whose permutation
        // basis can legitimately repeat; its contract is covered by the
        // non-finite case above.
        let g_next = Matrix::randn(rows, cols, 1.0, &mut rng);
        let _ = proj.refresh_and_project(&g_next);
        let _ = proj.basis(); // must not panic / stay poisoned
    }
}
