//! Observability contracts (PR 7):
//!
//! 1. **Read-only telemetry** — the training trajectory is
//!    `to_bits`-identical across `obs=off|counters|trace` for every
//!    engine preset: hooks observe values the step already computes and
//!    never feed back into the math.
//! 2. **Deterministic counters** — quantities that are functions of the
//!    data (ring all-reduce bytes) are identical for any thread-pool
//!    size, because the ring schedule depends only on shapes.
//! 3. **Deterministic event sets** — the per-lane ring merge
//!    (`RingSet::drain_all`, fixed ascending lane order; chunk `k` ↔
//!    ring `k`) records the same (name, layer) span set for 1 or 3
//!    lanes; only wall-clock timestamps may differ.
//! 4. **Loadable exports** — a traced run produces a Chrome-trace JSON
//!    array our own parser accepts, and per-refresh subspace-quality
//!    gauges for the low-rank layers.
//! 5. **Crash-durable metrics** — `JsonlWriter` flushes every
//!    `FLUSH_EVERY` records, so a run killed mid-stream (via the fault
//!    injector's worker-lane panic) leaves a valid JSONL prefix of
//!    exactly the flushed records, not a torn tail.
//!
//! The tier/sample/counter statics are process-global, so the tests that
//! touch them serialize on a file-local mutex.

use std::sync::{Arc, Mutex};

use fft_subspace::coordinator::{CommModel, Communicator};
use fft_subspace::obs::{self, trace::TraceWriter, ObsTier};
use fft_subspace::optim::{
    build_optimizer, LayerMeta, Optimizer, OptimizerConfig, OptimizerKind, ParamKind,
};
use fft_subspace::parallel::ThreadPool;
use fft_subspace::tensor::{Matrix, StateDtype};
use fft_subspace::train::{FaultInjector, FaultPlan};
use fft_subspace::util::csv::JsonlWriter;
use fft_subspace::util::json::{num, obj, s, Json};
use fft_subspace::util::Pcg64;

static OBS_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    OBS_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// Same mixed layer zoo as `tests/fault_recovery.rs`.
fn layer_zoo() -> Vec<LayerMeta> {
    vec![
        LayerMeta::new("wq", 48, 32, ParamKind::Linear),
        LayerMeta::new("w_gate", 32, 48, ParamKind::Linear),
        LayerMeta::new("wk", 40, 24, ParamKind::Linear),
        LayerMeta::new("wv", 32, 32, ParamKind::Linear),
        LayerMeta::new("norm", 1, 32, ParamKind::Norm),
        LayerMeta::new("embed", 64, 32, ParamKind::Embed),
    ]
}

fn grad_seq(metas: &[LayerMeta], steps: usize, seed: u64) -> Vec<Vec<Matrix>> {
    let mut rng = Pcg64::seed(seed);
    (0..steps)
        .map(|_| {
            metas
                .iter()
                .map(|m| Matrix::randn(m.rows, m.cols, 0.1, &mut rng))
                .collect()
        })
        .collect()
}

fn bits(params: &[Matrix]) -> Vec<Vec<u32>> {
    params
        .iter()
        .map(|p| p.data.iter().map(|v| v.to_bits()).collect())
        .collect()
}

fn cfg_with_threads(threads: usize) -> OptimizerConfig {
    OptimizerConfig {
        rank: 8,
        threads: Some(threads),
        update_interval: 3,
        state_dtype: StateDtype::F32,
        ..Default::default()
    }
}

fn zero_params(metas: &[LayerMeta]) -> Vec<Matrix> {
    metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect()
}

const SIX_PRESETS: [OptimizerKind; 6] = [
    OptimizerKind::DctAdamW,
    OptimizerKind::Trion,
    OptimizerKind::GaLore,
    OptimizerKind::Fira,
    OptimizerKind::Frugal,
    OptimizerKind::LdAdamW,
];

/// Contract 1: for every preset, 10 steps under each tier end on the
/// exact same parameter bits. The tier is set *before* the optimizer
/// builds (the engine sizes its rings then), exactly as the trainer does.
#[test]
fn trajectory_bits_identical_across_tiers() {
    let _g = lock();
    let metas = layer_zoo();
    let grads = grad_seq(&metas, 10, 42);
    for threads in [1usize, 3] {
        let cfg = cfg_with_threads(threads);
        for kind in &SIX_PRESETS {
            let mut reference: Option<Vec<Vec<u32>>> = None;
            for tier in [ObsTier::Off, ObsTier::Counters, ObsTier::Trace] {
                obs::set_tier(tier);
                obs::set_sample(1);
                obs::counters().reset();
                let mut opt = build_optimizer(kind, &metas, &cfg);
                let mut params = zero_params(&metas);
                for (step, g) in grads.iter().enumerate() {
                    opt.step(&mut params, g, 1e-2 / (1.0 + step as f32 * 0.1));
                }
                let b = bits(&params);
                match &reference {
                    None => reference = Some(b),
                    Some(r) => assert_eq!(
                        r,
                        &b,
                        "{} (threads={threads}): obs={} changed the trajectory",
                        kind.name(),
                        tier.name()
                    ),
                }
            }
        }
    }
    obs::set_tier(ObsTier::Off);
}

/// Contract 2: the `allreduce_bytes` counter is a pure function of the
/// reduced shapes and world size — identical for pool sizes 1, 3 and 8.
#[test]
fn allreduce_bytes_counter_stable_across_pool_sizes() {
    let _g = lock();
    obs::set_tier(ObsTier::Counters);
    let world = 4usize;
    let mut rng = Pcg64::seed(7);
    let shapes = [(48usize, 32usize), (40, 24), (1, 32)];
    let mut per_pool = Vec::new();
    for pool_n in [1usize, 3, 8] {
        obs::counters().reset();
        let pool = Arc::new(ThreadPool::new(pool_n));
        let mut comm = Communicator::with_pool(world, CommModel::default(), pool);
        for &(r, c) in &shapes {
            let proto = Matrix::randn(r, c, 0.5, &mut rng);
            let mut replicas: Vec<Matrix> =
                (0..world).map(|_| proto.clone()).collect();
            comm.all_reduce_mean(&mut replicas);
        }
        let counted = obs::counters().snapshot().allreduce_bytes;
        assert_eq!(
            counted, comm.stats.all_reduce_bytes,
            "pool={pool_n}: obs mirror diverged from CommStats"
        );
        assert!(counted > 0, "pool={pool_n}: nothing counted");
        per_pool.push(counted);
    }
    assert_eq!(per_pool[0], per_pool[1], "pool size changed all-reduce bytes");
    assert_eq!(per_pool[0], per_pool[2], "pool size changed all-reduce bytes");
    obs::set_tier(ObsTier::Off);
}

/// Drive `steps` engine steps under `obs=trace`, draining the rings after
/// every step. Returns the per-step sorted (name, layer) span sets and
/// the flat event list.
fn traced_run(
    threads: usize,
    steps: usize,
) -> (Vec<Vec<(String, u32)>>, Vec<obs::Event>) {
    let metas = layer_zoo();
    let grads = grad_seq(&metas, steps, 42);
    let cfg = cfg_with_threads(threads);
    let mut opt = build_optimizer(&OptimizerKind::DctAdamW, &metas, &cfg);
    let mut params = zero_params(&metas);
    let mut per_step = Vec::new();
    let mut all = Vec::new();
    let mut dropped = 0u64;
    for (step, g) in grads.iter().enumerate() {
        opt.step(&mut params, g, 1e-2 / (1.0 + step as f32 * 0.1));
        let mut events: Vec<obs::Event> = Vec::new();
        dropped += opt.drain_events(&mut events);
        let mut set: Vec<(String, u32)> =
            events.iter().map(|e| (e.name.to_string(), e.layer)).collect();
        set.sort();
        per_step.push(set);
        all.extend(events);
    }
    assert_eq!(dropped, 0, "rings drained every step must never drop");
    (per_step, all)
}

/// Contract 3: the recorded span set is identical for 1 and 3 lanes —
/// chunk-indexed rings merged in fixed lane order make the event set a
/// function of the layer list, not of the thread count.
#[test]
fn event_set_identical_across_lane_counts() {
    let _g = lock();
    obs::set_tier(ObsTier::Trace);
    obs::set_sample(1);
    let (seq, _) = traced_run(1, 8);
    let (par, _) = traced_run(3, 8);
    assert_eq!(seq, par, "span set depends on lane count");
    obs::set_tier(ObsTier::Off);
}

/// Contract 4: the Chrome-trace export parses back, and every DCT
/// low-rank layer reports in-range subspace-quality gauges at refreshes.
#[test]
fn trace_export_loads_and_gauges_cover_low_rank_layers() {
    let _g = lock();
    obs::set_tier(ObsTier::Trace);
    obs::set_sample(1);
    let metas = layer_zoo();
    let steps = 8usize;
    let grads = grad_seq(&metas, steps, 42);
    let cfg = cfg_with_threads(3);
    let mut opt = build_optimizer(&OptimizerKind::DctAdamW, &metas, &cfg);
    let mut params = zero_params(&metas);

    let path = std::env::temp_dir().join(format!(
        "fft_subspace_obs_trace_{}.json",
        std::process::id()
    ));
    let mut tw = TraceWriter::create(&path).unwrap();
    let mut gauges: std::collections::BTreeMap<String, Vec<obs::SubspaceQuality>> =
        Default::default();
    let mut names: std::collections::BTreeSet<&'static str> = Default::default();
    for (step, g) in grads.iter().enumerate() {
        opt.step(&mut params, g, 1e-2);
        let mut events: Vec<obs::Event> = Vec::new();
        opt.drain_events(&mut events);
        for e in &events {
            names.insert(e.name);
            tw.emit_event(e, step as u64).unwrap();
        }
        for (layer, _t, q) in opt.refresh_gauges() {
            gauges.entry(layer).or_default().push(q);
        }
    }
    tw.finish().unwrap();

    // span vocabulary: refresh steps and project-only steps both occurred
    for want in ["refresh", "project", "rule", "update", "dense"] {
        assert!(names.contains(want), "no {want:?} span recorded ({names:?})");
    }

    // the export is a loadable JSON array of complete events
    let text = std::fs::read_to_string(&path).unwrap();
    let doc = Json::parse(&text).unwrap();
    let events = doc.as_arr().unwrap();
    assert!(!events.is_empty());
    for e in events.iter().take(4) {
        assert_eq!(e.req("ph").unwrap().as_str().unwrap(), "X");
        assert!(e.req("name").unwrap().as_str().is_some());
        assert!(e.req("args").unwrap().req("step").unwrap().as_usize().is_some());
    }
    let _ = std::fs::remove_file(&path);

    // every DCT low-rank layer reported gauges, with multiple refreshes
    // inside 8 steps at update_interval=3, and all values in range
    for layer in ["wq", "w_gate", "wk", "wv"] {
        let qs = gauges.get(layer).unwrap_or_else(|| {
            panic!("no subspace-quality gauges for layer {layer} ({gauges:?})")
        });
        assert!(qs.len() >= 2, "{layer}: expected >=2 refreshes, got {}", qs.len());
        for q in qs {
            assert!(
                q.energy_ratio > 0.0 && q.energy_ratio <= 1.0 + 1e-6,
                "{layer}: energy_ratio {} out of range",
                q.energy_ratio
            );
            assert!(q.resid_norm.is_finite() && q.resid_norm >= 0.0);
            assert!(
                (0.0..=1.0).contains(&q.overlap),
                "{layer}: overlap {} out of range",
                q.overlap
            );
        }
        // the first refresh has no predecessor basis by definition
        assert_eq!(qs[0].overlap, 0.0, "{layer}: first refresh overlap");
    }
    obs::set_tier(ObsTier::Off);
}

/// Contract 5 (satellite 1): a run killed mid-stream keeps a valid JSONL
/// prefix of exactly the records the periodic flush already landed. The
/// kill is the fault injector's worker-lane panic; "losing the process"
/// is modeled by forgetting the writer so its `BufWriter` never flushes
/// the unflushed tail.
#[test]
fn mid_stream_kill_leaves_valid_jsonl_prefix() {
    let dir = std::env::temp_dir()
        .join(format!("fft_subspace_obs_kill_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let path = dir.join("metrics.jsonl");
    let mut writer = JsonlWriter::create(&path).unwrap();
    let kill_step = 50usize;
    let injector = FaultInjector::new(
        FaultPlan::parse(&format!("worker-fail@{kill_step}.0")).unwrap(),
    );

    let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        for step in 0..60usize {
            injector.maybe_fail_worker(step, 0);
            writer
                .record(&obj(vec![
                    ("step", num(step as f64)),
                    ("tag", s("alive")),
                ]))
                .unwrap();
        }
    }));
    assert!(run.is_err(), "the injected worker fault must fire");
    // the "process died": nothing flushes the buffered tail
    std::mem::forget(writer);

    // 50 records made it in before the kill; one periodic flush landed at
    // FLUSH_EVERY, the buffered remainder died with the writer
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(
        lines.len(),
        JsonlWriter::FLUSH_EVERY,
        "expected exactly one flush window on disk"
    );
    for (i, line) in lines.iter().enumerate() {
        let rec = Json::parse(line)
            .unwrap_or_else(|e| panic!("line {i} is torn: {e:#} ({line:?})"));
        assert_eq!(rec.req("step").unwrap().as_usize().unwrap(), i);
    }
    let _ = std::fs::remove_dir_all(&dir);
}
