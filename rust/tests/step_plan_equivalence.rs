//! The fused step-plan contract: `step-plan=fused` (compiled shape-batched
//! group programs) is **bit-identical** to `step-plan=interpreted` (the
//! retained per-layer loop, the differential-testing oracle) — for all six
//! engine presets, every state dtype, and every lane count.
//!
//! The layer zoo deliberately repeats shapes so the plan forms multi-layer
//! groups (the batched kernels actually stack rows), includes wide layers
//! (transpose orientation → staged gradients), a Bluestein width, and
//! dense-fallback params. Cadence T_u=3 exercises both group programs:
//! batched-similarity refresh steps (t=1,3,6,9) and batched-projection
//! steps in between (Trion/LDAdamW pin T_u=1 and refresh every step).
//!
//! Comparisons are on raw `to_bits` parameter trajectories after every
//! step, plus byte-equal `save_state` blobs at the end — the fused plan is
//! also invisible to the checkpoint fingerprint, so blobs from the two
//! modes must be interchangeable.

use fft_subspace::optim::{
    build_optimizer, LayerMeta, Optimizer, OptimizerConfig, OptimizerKind, ParamKind,
    StepPlanMode,
};
use fft_subspace::tensor::{Matrix, StateDtype};
use fft_subspace::util::Pcg64;

/// Shape-repeating zoo: three 48×32 + two wide 32×48 (same oriented group,
/// opposite orientation key) + two 40×24 (Bluestein width 24) + one square
/// 32×32, plus dense-path norm/embed params interleaved so group layer
/// indices are non-contiguous.
fn grouped_zoo() -> Vec<LayerMeta> {
    vec![
        LayerMeta::new("b0.wq", 48, 32, ParamKind::Linear),
        LayerMeta::new("b0.gate", 32, 48, ParamKind::Linear),
        LayerMeta::new("b0.norm", 1, 32, ParamKind::Norm),
        LayerMeta::new("b1.wq", 48, 32, ParamKind::Linear),
        LayerMeta::new("b1.wk", 40, 24, ParamKind::Linear),
        LayerMeta::new("embed", 64, 32, ParamKind::Embed),
        LayerMeta::new("b1.gate", 32, 48, ParamKind::Linear),
        LayerMeta::new("b2.wq", 48, 32, ParamKind::Linear),
        LayerMeta::new("b2.wk", 40, 24, ParamKind::Linear),
        LayerMeta::new("b2.wv", 32, 32, ParamKind::Linear),
    ]
}

fn grad_seq(metas: &[LayerMeta], steps: usize, seed: u64) -> Vec<Vec<Matrix>> {
    let mut rng = Pcg64::seed(seed);
    (0..steps)
        .map(|_| {
            metas
                .iter()
                .map(|m| Matrix::randn(m.rows, m.cols, 0.1, &mut rng))
                .collect()
        })
        .collect()
}

fn bits(params: &[Matrix]) -> Vec<Vec<u32>> {
    params
        .iter()
        .map(|p| p.data.iter().map(|v| v.to_bits()).collect())
        .collect()
}

fn decaying_lr(step: usize) -> f32 {
    1e-2 / (1.0 + step as f32 * 0.1)
}

fn cfg(state_dtype: StateDtype, lanes: usize, plan: StepPlanMode) -> OptimizerConfig {
    OptimizerConfig {
        rank: 8,
        threads: Some(lanes),
        update_interval: 3,
        state_dtype,
        step_plan: plan,
        ..Default::default()
    }
}

const SIX_PRESETS: [OptimizerKind; 6] = [
    OptimizerKind::DctAdamW,
    OptimizerKind::Trion,
    OptimizerKind::GaLore,
    OptimizerKind::Fira,
    OptimizerKind::Frugal,
    OptimizerKind::LdAdamW,
];

const STEPS: usize = 10;

/// Run one preset at one dtype under (plan, lanes), returning the per-step
/// parameter bit trajectory and the final state blob.
fn run(
    kind: &OptimizerKind,
    state_dtype: StateDtype,
    lanes: usize,
    plan: StepPlanMode,
    grads: &[Vec<Matrix>],
    metas: &[LayerMeta],
) -> (Vec<Vec<Vec<u32>>>, Vec<u8>) {
    let mut opt = build_optimizer(kind, metas, &cfg(state_dtype, lanes, plan));
    let mut params: Vec<Matrix> =
        metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
    let mut traj = Vec::with_capacity(grads.len());
    for (step, g) in grads.iter().enumerate() {
        opt.step(&mut params, g, decaying_lr(step));
        traj.push(bits(&params));
    }
    let blob = opt.save_state().expect("engine presets support state blobs");
    (traj, blob)
}

fn assert_fused_matches_oracle(state_dtype: StateDtype) {
    let metas = grouped_zoo();
    let grads = grad_seq(&metas, STEPS, 42);
    for kind in &SIX_PRESETS {
        // the oracle: single-lane interpreted per-layer loop
        let (oracle_traj, oracle_blob) = run(
            kind,
            state_dtype,
            1,
            StepPlanMode::Interpreted,
            &grads,
            &metas,
        );
        for lanes in [1usize, 3, 8] {
            for plan in [StepPlanMode::Fused, StepPlanMode::Interpreted] {
                let (traj, blob) = run(kind, state_dtype, lanes, plan, &grads, &metas);
                for (step, (got, want)) in traj.iter().zip(&oracle_traj).enumerate() {
                    assert_eq!(
                        got,
                        want,
                        "{} (dtype={}, lanes={lanes}, plan={}): step {} diverged \
                         from the interpreted oracle",
                        kind.name(),
                        state_dtype.name(),
                        plan.name(),
                        step + 1
                    );
                }
                // state blobs are mode-invariant (plans are derived state,
                // outside the fingerprint)
                assert_eq!(
                    blob,
                    oracle_blob,
                    "{} (dtype={}, lanes={lanes}, plan={}): final state blob \
                     differs",
                    kind.name(),
                    state_dtype.name(),
                    plan.name()
                );
            }
        }
    }
}

#[test]
fn six_presets_fused_equals_interpreted_f32() {
    assert_fused_matches_oracle(StateDtype::F32);
}

#[test]
fn six_presets_fused_equals_interpreted_bf16() {
    assert_fused_matches_oracle(StateDtype::Bf16);
}

#[test]
fn six_presets_fused_equals_interpreted_q8() {
    assert_fused_matches_oracle(StateDtype::Q8);
}

#[test]
fn fused_respects_every_step_cadence_too() {
    // T_u=1 (refresh every step): the batched-similarity program runs on
    // every step and the batched-projection program never does — the other
    // boundary of the cadence space.
    let metas = grouped_zoo();
    let grads = grad_seq(&metas, 6, 7);
    for kind in [OptimizerKind::DctAdamW, OptimizerKind::Fira, OptimizerKind::Frugal] {
        let every = |plan| OptimizerConfig {
            update_interval: 1,
            ..cfg(StateDtype::F32, 3, plan)
        };
        let run_with = |c: &OptimizerConfig| {
            let mut opt = build_optimizer(&kind, &metas, c);
            let mut params: Vec<Matrix> =
                metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
            for (step, g) in grads.iter().enumerate() {
                opt.step(&mut params, g, decaying_lr(step));
            }
            bits(&params)
        };
        assert_eq!(
            run_with(&every(StepPlanMode::Fused)),
            run_with(&every(StepPlanMode::Interpreted)),
            "{} T_u=1 fused diverged",
            kind.name()
        );
    }
}

#[test]
fn fused_engine_rebuilds_plan_on_restore() {
    // save under fused → restore into a fused engine → the rebuilt plan
    // continues the exact trajectory (plans are derived, not serialized).
    let metas = grouped_zoo();
    let (n, k) = (9usize, 4usize);
    let grads = grad_seq(&metas, n, 11);
    let c = cfg(StateDtype::Q8, 3, StepPlanMode::Fused);
    let mut ref_opt = build_optimizer(&OptimizerKind::DctAdamW, &metas, &c);
    let mut ref_params: Vec<Matrix> =
        metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
    for (step, g) in grads.iter().enumerate() {
        ref_opt.step(&mut ref_params, g, decaying_lr(step));
    }
    let mut opt_a = build_optimizer(&OptimizerKind::DctAdamW, &metas, &c);
    let mut params: Vec<Matrix> =
        metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
    for (step, g) in grads.iter().take(k).enumerate() {
        opt_a.step(&mut params, g, decaying_lr(step));
    }
    let blob = opt_a.save_state().unwrap();
    let mut opt_b = build_optimizer(&OptimizerKind::DctAdamW, &metas, &c);
    opt_b.load_state(&blob).unwrap();
    for (step, g) in grads.iter().enumerate().skip(k) {
        opt_b.step(&mut params, g, decaying_lr(step));
    }
    assert_eq!(bits(&ref_params), bits(&params));
}
