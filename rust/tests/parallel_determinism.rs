//! Thread-count invariance proofs for the parallel execution engine.
//!
//! The determinism contract (ROADMAP §Parallel runtime): every parallel
//! path — row-blocked matmuls, per-row-batched Makhoul, disjoint-layer
//! optimizer stepping, the threaded ring all-reduce — produces **the exact
//! bits** of its sequential twin for any thread count. These tests pin a
//! 1-lane pool (fully sequential inline execution) against multi-lane
//! pools and assert `==` on `f32` buffers, not approximate closeness.

use fft_subspace::coordinator::{CommModel, Communicator, WorkerSet};
use fft_subspace::optim::{
    build_optimizer, EfMode, LayerMeta, Optimizer, OptimizerConfig, OptimizerKind,
    OptimizerSpec, ParamKind, ResidualKind,
};
use fft_subspace::parallel::ThreadPool;
use fft_subspace::projection::{ProjectionKind, RankNorm};
use fft_subspace::tensor::{
    matmul_a_bt, matmul_a_bt_into_on, matmul_at_b, matmul_at_b_into_on, matmul,
    matmul_into_on, Matrix,
};
use fft_subspace::util::Pcg64;
use std::sync::Arc;

/// A small model zoo: tall, wide (transpose orientation), square,
/// Bluestein-width, and dense-path layers — every orientation branch.
fn layer_zoo() -> Vec<LayerMeta> {
    vec![
        LayerMeta::new("wq", 48, 32, ParamKind::Linear),
        LayerMeta::new("w_gate", 32, 48, ParamKind::Linear),
        LayerMeta::new("wk", 40, 24, ParamKind::Linear),
        LayerMeta::new("wv", 32, 32, ParamKind::Linear),
        LayerMeta::new("w_down", 56, 28, ParamKind::Linear),
        LayerMeta::new("norm", 1, 32, ParamKind::Norm),
        LayerMeta::new("embed", 64, 32, ParamKind::Embed),
    ]
}

fn zoo_grads(metas: &[LayerMeta], seed: u64) -> Vec<Vec<Matrix>> {
    let mut rng = Pcg64::seed(seed);
    (0..6)
        .map(|_| {
            metas
                .iter()
                .map(|m| Matrix::randn(m.rows, m.cols, 0.1, &mut rng))
                .collect()
        })
        .collect()
}

/// Run `steps` optimizer steps at a pinned lane count; return final params.
fn run_optimizer(kind: &OptimizerKind, threads: usize, metas: &[LayerMeta],
                 grad_seq: &[Vec<Matrix>]) -> Vec<Matrix> {
    let cfg = OptimizerConfig {
        rank: 8,
        update_interval: 2, // refresh AND project-only steps in the window
        threads: Some(threads),
        // SVD/DCT both exercised across the six kinds; keep each kind on
        // its own default projection except the pluggable three, which get
        // the paper's DCT so the Makhoul path runs under threading.
        projection: ProjectionKind::Dct { norm: RankNorm::L2, use_makhoul: true },
        ..Default::default()
    };
    let mut opt = build_optimizer(kind, metas, &cfg);
    let mut params: Vec<Matrix> = metas
        .iter()
        .map(|m| Matrix::zeros(m.rows, m.cols))
        .collect();
    for grads in grad_seq {
        opt.step(&mut params, grads, 1e-3);
    }
    params
}

#[test]
fn all_six_low_rank_optimizers_bit_identical_1_vs_n_threads() {
    let metas = layer_zoo();
    let grad_seq = zoo_grads(&metas, 42);
    for kind in [
        OptimizerKind::DctAdamW,
        OptimizerKind::Trion,
        OptimizerKind::GaLore,
        OptimizerKind::Fira,
        OptimizerKind::Frugal,
        OptimizerKind::LdAdamW,
    ] {
        let sequential = run_optimizer(&kind, 1, &metas, &grad_seq);
        for threads in [3usize, 8] {
            let parallel = run_optimizer(&kind, threads, &metas, &grad_seq);
            for (i, (a, b)) in sequential.iter().zip(&parallel).enumerate() {
                assert_eq!(
                    a, b,
                    "{}: layer {} ({}) diverged at {} threads",
                    kind.name(),
                    i,
                    metas[i].name,
                    threads
                );
            }
        }
    }
}

#[test]
fn engine_grid_combo_bit_identical_1_vs_n_threads() {
    // A non-preset engine composition (DCT source + GaLore cadence + Q8
    // error feedback) must satisfy the same any-thread-count contract as
    // the six presets — the determinism property belongs to the engine's
    // step loop, not to any particular policy combination.
    let metas = layer_zoo();
    let grad_seq = zoo_grads(&metas, 23);
    // determinism must hold for every state dtype (typed stores quantize
    // per layer, never across layers); `make test-matrix` sweeps this knob
    let dtype = fft_subspace::tensor::StateDtype::from_env()
        .unwrap_or(fft_subspace::tensor::StateDtype::Bf16);
    let combo = |threads: usize| {
        OptimizerSpec::galore(8)
            .projection(ProjectionKind::Dct { norm: RankNorm::L2, use_makhoul: true })
            .residual(ResidualKind::ErrorFeedback(EfMode::Q8))
            .update_interval(2)
            .state_dtype(dtype)
            .threads(Some(threads))
    };
    let mut params_by_lanes = Vec::new();
    for threads in [1usize, 3, 8] {
        let mut opt = combo(threads).build(&metas);
        let mut params: Vec<Matrix> =
            metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
        for grads in &grad_seq {
            opt.step(&mut params, grads, 1e-3);
        }
        params_by_lanes.push((threads, params));
    }
    let (_, reference) = &params_by_lanes[0];
    for (threads, params) in &params_by_lanes[1..] {
        for (i, (a, b)) in reference.iter().zip(params).enumerate() {
            assert_eq!(
                a, b,
                "engine combo: layer {} ({}) diverged at {} threads",
                i, metas[i].name, threads
            );
        }
    }
}

#[test]
fn simd_kernels_by_thread_count_bit_identical() {
    // SIMD × {1,3,8} threads: the auto-detected backend (vectorized
    // wherever the CPU allows) must keep the any-thread-count contract —
    // the SIMD kernels never touch per-element summation order (see
    // `crate::simd`), so the PR-2 guarantee is backend-independent. This
    // test deliberately does NOT flip the process-global backend override
    // (tests in this binary run concurrently and would observe the flip
    // mid-kernel); the forced-scalar × backend × lane-count cross matrix
    // lives in tests/simd_bit_identity.rs, which serializes every test on
    // the override lock, and in `make test-matrix` at the process level.
    let metas = layer_zoo();
    let grad_seq = zoo_grads(&metas, 17);
    println!(
        "simd × threads matrix under auto backend: {}",
        fft_subspace::simd::backend().name()
    );
    // raw bit patterns, not float PartialEq — `-0.0 == 0.0` must not mask
    // a sign divergence
    let bits = |m: &Matrix| -> Vec<u32> { m.data.iter().map(|v| v.to_bits()).collect() };
    let reference = run_optimizer(&OptimizerKind::DctAdamW, 1, &metas, &grad_seq);
    for threads in [3usize, 8] {
        let got = run_optimizer(&OptimizerKind::DctAdamW, threads, &metas, &grad_seq);
        for (i, (a, b)) in reference.iter().zip(&got).enumerate() {
            assert_eq!(a.shape(), b.shape(), "layer {i} shape at {threads} threads");
            assert_eq!(bits(a), bits(b), "dct-adamw layer {i} diverged at {threads} threads");
        }
    }
}

#[test]
fn prop_parallel_matmul_family_bit_identical() {
    // Random shapes × pools {2, 3, 8} against the sequential kernels
    // (which the allocating APIs delegate to).
    let pools = [ThreadPool::new(2), ThreadPool::new(3), ThreadPool::new(8)];
    let mut rng = Pcg64::seed(7);
    for trial in 0..24 {
        let m = 1 + (rng.next_u64() % 67) as usize;
        let k = 1 + (rng.next_u64() % 41) as usize;
        let n = 1 + (rng.next_u64() % 41) as usize;
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let at = Matrix::randn(k, m, 1.0, &mut rng);
        let bt = Matrix::randn(n, k, 1.0, &mut rng);
        let mut out = Matrix::randn(2, 2, 1.0, &mut rng); // dirty buffer
        for pool in &pools {
            matmul_into_on(pool, &a, &b, &mut out);
            assert_eq!(out, matmul(&a, &b), "trial {trial} matmul t={}", pool.threads());
            matmul_at_b_into_on(pool, &at, &b, &mut out);
            assert_eq!(out, matmul_at_b(&at, &b), "trial {trial} at_b t={}", pool.threads());
            matmul_a_bt_into_on(pool, &a, &bt, &mut out);
            assert_eq!(out, matmul_a_bt(&a, &bt), "trial {trial} a_bt t={}", pool.threads());
        }
    }
}

#[test]
fn makhoul_parallel_rows_bit_identical() {
    // Split (even), Bluestein (odd), pow2 — all widths through pools 1..8.
    let pools = [ThreadPool::new(1), ThreadPool::new(4), ThreadPool::new(8)];
    let mut rng = Pcg64::seed(11);
    for n in [8usize, 24, 33, 64, 100] {
        let plan = fft_subspace::fft::cached_plan(n);
        let g = Matrix::randn(23, n, 1.0, &mut rng);
        let mut want = Matrix::zeros(1, 1);
        plan.run_into(&g, &mut want);
        for pool in &pools {
            let mut got = Matrix::randn(3, 3, 1.0, &mut rng);
            plan.run_into_on(pool, &g, &mut got);
            assert_eq!(got, want, "n={n} threads={}", pool.threads());
        }
    }
}

#[test]
fn threaded_ring_all_reduce_bit_identical_with_equal_stats() {
    let mut rng = Pcg64::seed(3);
    for w in [2usize, 4, 7] {
        let bufs: Vec<Matrix> =
            (0..w).map(|_| Matrix::randn(9, 13, 1.0, &mut rng)).collect();
        let mut seq = bufs.clone();
        let mut comm_seq = Communicator::new(w, CommModel::default());
        comm_seq.all_reduce_mean(&mut seq);
        for threads in [2usize, 5] {
            let mut par = bufs.clone();
            let mut comm_par = Communicator::with_pool(
                w,
                CommModel::default(),
                Arc::new(ThreadPool::new(threads)),
            );
            comm_par.all_reduce_mean(&mut par);
            assert_eq!(seq, par, "w={w} threads={threads}");
            assert_eq!(
                comm_seq.stats.all_reduce_bytes,
                comm_par.stats.all_reduce_bytes
            );
            assert_eq!(comm_seq.stats.modeled_secs, comm_par.stats.modeled_secs);
        }
    }
}

#[test]
fn worker_set_results_independent_of_thread_count() {
    // Per-worker deterministic "gradients" (own RNG substream) come back in
    // worker order whatever the pool size — the trainer's staging pattern.
    let grad = |w: usize| {
        let mut rng = Pcg64::new(99, w as u64);
        Matrix::randn(6, 6, 1.0, &mut rng)
    };
    let want: Vec<Matrix> = (0..5).map(grad).collect();
    for threads in [1usize, 3, 8] {
        let ws = WorkerSet::new(5, Arc::new(ThreadPool::new(threads)));
        assert_eq!(ws.run(grad), want, "threads={threads}");
    }
}
