//! The checkpoint-v2 resume contract: saving mid-run and restoring into a
//! **freshly built** optimizer must reproduce the uninterrupted trajectory
//! to the bit — for all six engine presets, the dense AdamW baseline, and
//! every state dtype.
//!
//! The interruption point (k=5 of N=11, cadence T_u=3) deliberately sits
//! between subspace refreshes, so the blob must carry everything a later
//! step reads: the step counter, the typed moment/momentum stores, the
//! held subspace (indices / dense bases / warm flags / RNG streams), the
//! rotation snapshots and the error-feedback residuals. Comparisons are on
//! raw `to_bits` patterns — a missing or re-quantized byte anywhere shows
//! up as a divergence within a step or two.
//!
//! The file-level format (`FFTSUBv2` roundtrip, v1 backward compat,
//! corrupt-file rejection) is covered in `train::checkpoint`'s unit tests;
//! this suite additionally pins the end-to-end file path for one preset.

use fft_subspace::optim::{
    build_optimizer, LayerMeta, Optimizer, OptimizerConfig, OptimizerKind, ParamKind,
};
use fft_subspace::tensor::{Matrix, StateDtype};
use fft_subspace::train::checkpoint::{self, TrainState};
use fft_subspace::util::Pcg64;

/// Mixed layer zoo: tall, wide (transpose orientation), a Bluestein width
/// (24), square, plus dense-path params — the shapes the equivalence suite
/// uses.
fn layer_zoo() -> Vec<LayerMeta> {
    vec![
        LayerMeta::new("wq", 48, 32, ParamKind::Linear),
        LayerMeta::new("w_gate", 32, 48, ParamKind::Linear),
        LayerMeta::new("wk", 40, 24, ParamKind::Linear),
        LayerMeta::new("wv", 32, 32, ParamKind::Linear),
        LayerMeta::new("norm", 1, 32, ParamKind::Norm),
        LayerMeta::new("embed", 64, 32, ParamKind::Embed),
    ]
}

fn grad_seq(metas: &[LayerMeta], steps: usize, seed: u64) -> Vec<Vec<Matrix>> {
    let mut rng = Pcg64::seed(seed);
    (0..steps)
        .map(|_| {
            metas
                .iter()
                .map(|m| Matrix::randn(m.rows, m.cols, 0.1, &mut rng))
                .collect()
        })
        .collect()
}

fn bits(params: &[Matrix]) -> Vec<Vec<u32>> {
    params
        .iter()
        .map(|p| p.data.iter().map(|v| v.to_bits()).collect())
        .collect()
}

fn decaying_lr(step: usize) -> f32 {
    1e-2 / (1.0 + step as f32 * 0.1)
}

fn cfg_for(state_dtype: StateDtype) -> OptimizerConfig {
    OptimizerConfig {
        rank: 8,
        threads: Some(1),
        // refresh cadence 3: the save point (k=5) sits mid-cycle, and the
        // resumed run crosses two more refreshes (t=6, t=9) — Trion and
        // LDAdamW pin T_u=1 and refresh every step regardless
        update_interval: 3,
        state_dtype,
        ..Default::default()
    }
}

const SIX_PRESETS: [OptimizerKind; 6] = [
    OptimizerKind::DctAdamW,
    OptimizerKind::Trion,
    OptimizerKind::GaLore,
    OptimizerKind::Fira,
    OptimizerKind::Frugal,
    OptimizerKind::LdAdamW,
];

/// Core property: train N uninterrupted vs. train k → save_state → fresh
/// optimizer → load_state → train N−k. Bit-equal params, and bit-equal
/// state blobs at the end.
fn assert_resume_bit_identical(kind: &OptimizerKind, state_dtype: StateDtype) {
    let metas = layer_zoo();
    let (n, k) = (11usize, 5usize);
    let grads = grad_seq(&metas, n, 42);
    let cfg = cfg_for(state_dtype);

    // uninterrupted reference
    let mut ref_opt = build_optimizer(kind, &metas, &cfg);
    let mut ref_params: Vec<Matrix> =
        metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
    for (step, g) in grads.iter().enumerate() {
        ref_opt.step(&mut ref_params, g, decaying_lr(step));
    }

    // interrupted at k, resumed into a FRESH optimizer
    let mut opt_a = build_optimizer(kind, &metas, &cfg);
    let mut params: Vec<Matrix> =
        metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
    for (step, g) in grads.iter().take(k).enumerate() {
        opt_a.step(&mut params, g, decaying_lr(step));
    }
    let blob = opt_a
        .save_state()
        .expect("engine presets support state checkpointing");
    drop(opt_a);
    let mut opt_b = build_optimizer(kind, &metas, &cfg);
    opt_b
        .load_state(&blob)
        .unwrap_or_else(|e| panic!("{} restore failed: {e:#}", kind.name()));
    for (step, g) in grads.iter().enumerate().skip(k) {
        opt_b.step(&mut params, g, decaying_lr(step));
    }

    assert_eq!(
        bits(&ref_params),
        bits(&params),
        "{} (state-dtype={}): resumed trajectory diverged",
        kind.name(),
        state_dtype.name()
    );
    // the final optimizer states agree byte-for-byte too
    assert_eq!(
        ref_opt.save_state().unwrap(),
        opt_b.save_state().unwrap(),
        "{} (state-dtype={}): final state blobs differ",
        kind.name(),
        state_dtype.name()
    );
}

#[test]
fn six_presets_resume_bit_identically_f32() {
    for kind in &SIX_PRESETS {
        assert_resume_bit_identical(kind, StateDtype::F32);
    }
}

#[test]
fn six_presets_resume_bit_identically_bf16() {
    for kind in &SIX_PRESETS {
        assert_resume_bit_identical(kind, StateDtype::Bf16);
    }
}

#[test]
fn six_presets_resume_bit_identically_q8() {
    for kind in &SIX_PRESETS {
        assert_resume_bit_identical(kind, StateDtype::Q8);
    }
}

#[test]
fn env_selected_dtype_resumes_bit_identically() {
    // `make test-matrix` drives FFT_SUBSPACE_STATE_DTYPE over {f32, bf16};
    // redundant with the fixed sweeps above but keeps the knob honest.
    let d = StateDtype::from_env().unwrap_or(StateDtype::F32);
    assert_resume_bit_identical(&OptimizerKind::DctAdamW, d);
}

#[test]
fn resume_crosses_step_plan_modes_bit_identically() {
    // Step plans are derived state: they are rebuilt at load_state and
    // excluded from the checkpoint fingerprint, so a blob saved under the
    // fused shape-batched plan restores into an interpreted engine (and
    // vice versa) and continues the exact trajectory.
    use fft_subspace::optim::StepPlanMode;
    let metas = layer_zoo();
    let (n, k) = (11usize, 5usize);
    let grads = grad_seq(&metas, n, 42);
    let fused = OptimizerConfig {
        step_plan: StepPlanMode::Fused,
        ..cfg_for(StateDtype::Q8)
    };
    let interp = OptimizerConfig {
        step_plan: StepPlanMode::Interpreted,
        ..cfg_for(StateDtype::Q8)
    };
    for kind in &SIX_PRESETS {
        // uninterrupted fused reference
        let mut ref_opt = build_optimizer(kind, &metas, &fused);
        let mut ref_params: Vec<Matrix> =
            metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
        for (step, g) in grads.iter().enumerate() {
            ref_opt.step(&mut ref_params, g, decaying_lr(step));
        }
        for (save_cfg, load_cfg, label) in
            [(&fused, &interp, "fused→interpreted"), (&interp, &fused, "interpreted→fused")]
        {
            let mut opt_a = build_optimizer(kind, &metas, save_cfg);
            let mut params: Vec<Matrix> =
                metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
            for (step, g) in grads.iter().take(k).enumerate() {
                opt_a.step(&mut params, g, decaying_lr(step));
            }
            let blob = opt_a.save_state().unwrap();
            let mut opt_b = build_optimizer(kind, &metas, load_cfg);
            opt_b
                .load_state(&blob)
                .unwrap_or_else(|e| panic!("{} {label} restore failed: {e:#}", kind.name()));
            for (step, g) in grads.iter().enumerate().skip(k) {
                opt_b.step(&mut params, g, decaying_lr(step));
            }
            assert_eq!(
                bits(&ref_params),
                bits(&params),
                "{} ({label}): cross-mode resume diverged",
                kind.name()
            );
        }
    }
}

#[test]
fn dense_adamw_resumes_bit_identically() {
    let metas = layer_zoo();
    let (n, k) = (9usize, 4usize);
    let grads = grad_seq(&metas, n, 7);
    for state_dtype in [StateDtype::F32, StateDtype::Bf16] {
        let cfg = cfg_for(state_dtype);
        let kind = OptimizerKind::AdamW;
        let mut ref_opt = build_optimizer(&kind, &metas, &cfg);
        let mut ref_params: Vec<Matrix> =
            metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
        for (step, g) in grads.iter().enumerate() {
            ref_opt.step(&mut ref_params, g, decaying_lr(step));
        }
        let mut opt_a = build_optimizer(&kind, &metas, &cfg);
        let mut params: Vec<Matrix> =
            metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
        for (step, g) in grads.iter().take(k).enumerate() {
            opt_a.step(&mut params, g, decaying_lr(step));
        }
        let blob = opt_a.save_state().unwrap();
        let mut opt_b = build_optimizer(&kind, &metas, &cfg);
        opt_b.load_state(&blob).unwrap();
        for (step, g) in grads.iter().enumerate().skip(k) {
            opt_b.step(&mut params, g, decaying_lr(step));
        }
        assert_eq!(bits(&ref_params), bits(&params), "adamw {state_dtype:?}");
    }
}

#[test]
fn resume_rejects_mismatched_composition() {
    let metas = layer_zoo();
    let grads = grad_seq(&metas, 2, 3);
    let cfg = cfg_for(StateDtype::F32);
    let mut opt = build_optimizer(&OptimizerKind::DctAdamW, &metas, &cfg);
    let mut params: Vec<Matrix> =
        metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
    for g in &grads {
        opt.step(&mut params, g, 1e-2);
    }
    let blob = opt.save_state().unwrap();
    // different preset
    let mut other = build_optimizer(&OptimizerKind::Trion, &metas, &cfg);
    assert!(other.load_state(&blob).is_err());
    // different rank
    let cfg_r = OptimizerConfig { rank: 4, ..cfg_for(StateDtype::F32) };
    let mut other = build_optimizer(&OptimizerKind::DctAdamW, &metas, &cfg_r);
    assert!(other.load_state(&blob).is_err());
    // different state dtype
    let mut other =
        build_optimizer(&OptimizerKind::DctAdamW, &metas, &cfg_for(StateDtype::Q8));
    assert!(other.load_state(&blob).is_err());
    // corrupt blob
    let mut same = build_optimizer(&OptimizerKind::DctAdamW, &metas, &cfg);
    assert!(same.load_state(&blob[..blob.len() / 2]).is_err());
    let mut garbage = blob.clone();
    for b in garbage.iter_mut().skip(blob.len() - 16) {
        *b ^= 0xA5;
    }
    let mut same = build_optimizer(&OptimizerKind::DctAdamW, &metas, &cfg);
    // trailing-byte corruption either fails a payload read or survives into
    // a store whose dtype/shape check rejects it — never a panic
    let _ = same.load_state(&garbage);
}

#[test]
fn v2_checkpoint_file_roundtrips_the_resume_state() {
    // end-to-end through the on-disk format: save_v2 → load_full →
    // load_state reproduces the exact optimizer state
    let metas = layer_zoo();
    let grads = grad_seq(&metas, 6, 99);
    let cfg = cfg_for(StateDtype::Bf16);
    let mut opt = build_optimizer(&OptimizerKind::DctAdamW, &metas, &cfg);
    let mut params: Vec<Matrix> =
        metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
    for (step, g) in grads.iter().take(4).enumerate() {
        opt.step(&mut params, g, decaying_lr(step));
    }
    let state = TrainState {
        step: 4,
        optimizer: opt.name().to_string(),
        opt_state: opt.save_state().unwrap(),
        sync: Vec::new(),
    };
    let path = std::env::temp_dir().join("fft_subspace_resume_e2e.bin");
    checkpoint::save_v2(&path, &params, &state).unwrap();

    let ck = checkpoint::load_full(&path).unwrap();
    assert_eq!(bits(&ck.params), bits(&params));
    let restored = ck.state.unwrap();
    assert_eq!(restored.step, 4);
    assert_eq!(restored.optimizer, "dct-adamw+m:bf16");
    let mut opt_b = build_optimizer(&OptimizerKind::DctAdamW, &metas, &cfg);
    opt_b.load_state(&restored.opt_state).unwrap();
    let mut params_b = ck.params;
    // both finish the run; trajectories agree to the bit
    for (step, g) in grads.iter().enumerate().skip(4) {
        opt.step(&mut params, g, decaying_lr(step));
        opt_b.step(&mut params_b, g, decaying_lr(step));
    }
    assert_eq!(bits(&params), bits(&params_b));

    // v1 files still load as params-only (backward compat)
    let v1_path = std::env::temp_dir().join("fft_subspace_resume_v1.bin");
    checkpoint::save(&v1_path, &params).unwrap();
    let v1 = checkpoint::load_full(&v1_path).unwrap();
    assert!(v1.state.is_none());
    assert_eq!(bits(&v1.params), bits(&params));
}

#[test]
fn seeded_sources_resume_their_rng_streams() {
    // Random / RandPerm sources draw from per-layer RNG streams on every
    // refresh — the blob must carry the stream state, not just the current
    // basis, or the first post-resume refresh diverges.
    use fft_subspace::optim::OptimizerSpec;
    use fft_subspace::projection::ProjectionKind;
    let metas = layer_zoo();
    let (n, k) = (11usize, 5usize);
    let grads = grad_seq(&metas, n, 17);
    for proj in [ProjectionKind::Random, ProjectionKind::RandPerm] {
        let spec = OptimizerSpec::frugal(8)
            .projection(proj.clone())
            .update_interval(3)
            .threads(Some(1))
            .seed(5);
        let mut ref_opt = spec.build(&metas);
        let mut ref_params: Vec<Matrix> =
            metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
        for (step, g) in grads.iter().enumerate() {
            ref_opt.step(&mut ref_params, g, decaying_lr(step));
        }
        let mut opt_a = spec.build(&metas);
        let mut params: Vec<Matrix> =
            metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
        for (step, g) in grads.iter().take(k).enumerate() {
            opt_a.step(&mut params, g, decaying_lr(step));
        }
        let blob = opt_a.serialize_state();
        let mut opt_b = spec.build(&metas);
        opt_b.restore_state(&blob).unwrap();
        for (step, g) in grads.iter().enumerate().skip(k) {
            opt_b.step(&mut params, g, decaying_lr(step));
        }
        assert_eq!(
            bits(&ref_params),
            bits(&params),
            "{}: seeded source diverged after resume",
            proj.name()
        );
    }
}
