//! SIMD ↔ scalar bit-identity proofs for every dispatched kernel.
//!
//! Each test runs the same computation once under the forced scalar
//! backend (`FFT_SUBSPACE_SIMD=0`'s code path) and once under the
//! auto-detected backend (AVX2/NEON where available), then asserts
//! equality on the **raw bit patterns** (`to_bits`, never float
//! `PartialEq` — which would let a `-0.0`/`+0.0` divergence slip through
//! and would choke on NaN). Shapes sweep odd sizes: lane-width remainders,
//! fewer elements than one vector, empty matrices — the cases where a
//! vector kernel's scalar tail must take over with the identical op
//! sequence.
//!
//! On machines whose CPU offers no vector backend the comparisons are
//! scalar-vs-scalar and pass trivially — the `make test-matrix` target
//! additionally runs the whole suite under `FFT_SUBSPACE_SIMD={0,1}` so CI
//! covers the env-var path end to end.
//!
//! The backend override is process-global, so every test serializes on one
//! mutex (poison-tolerant: one failed test must not cascade) and a drop
//! guard restores auto-detection even when an assertion fires mid-run.

use std::sync::Mutex;

use fft_subspace::fft::{cached_plan, fft_inplace, Complex};
use fft_subspace::optim::common::AdamState;
use fft_subspace::optim::{
    adam_moments_into, build_optimizer, AdamScalars, LayerMeta, Optimizer,
    OptimizerConfig, OptimizerKind, ParamKind,
};
use fft_subspace::projection::{select_top_columns, RankNorm};
use fft_subspace::simd::{backend, set_backend_override, Backend};
use fft_subspace::tensor::{matmul, matmul_a_bt, matmul_at_b, Matrix};
use fft_subspace::util::Pcg64;

static OVERRIDE_LOCK: Mutex<()> = Mutex::new(());

/// Restores backend auto-detection on drop — assertion panics inside a
/// comparison must not leave the process forced to one backend.
struct OverrideGuard;
impl Drop for OverrideGuard {
    fn drop(&mut self) {
        set_backend_override(None);
    }
}

/// Run `f` once per backend (scalar forced, then auto) and return both
/// results; the caller asserts bitwise equality. Holds the (poison-
/// tolerant) override lock for the whole comparison.
fn scalar_vs_auto<R>(mut f: impl FnMut() -> R) -> (R, R) {
    let _lock = OVERRIDE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = OverrideGuard;
    set_backend_override(Some(Backend::Scalar));
    let scalar = f();
    set_backend_override(None);
    let auto = f();
    (scalar, auto)
}

// ---- bit-pattern projections (float PartialEq is NOT bit identity) -----

fn mat_bits(m: &Matrix) -> (usize, usize, Vec<u32>) {
    (m.rows, m.cols, m.data.iter().map(|v| v.to_bits()).collect())
}

fn f32_bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn f64_bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn complex_bits(z: &[Complex]) -> Vec<(u64, u64)> {
    z.iter().map(|c| (c.re.to_bits(), c.im.to_bits())).collect()
}

#[test]
fn report_backend() {
    // Not an assertion — documents in the test log which backend the auto
    // path exercised on this machine.
    println!("simd_bit_identity: auto backend = {}", backend().name());
}

#[test]
fn matmul_family_bit_identical_over_odd_shapes() {
    // Shapes straddle every lane boundary: below one vector, exact
    // multiples, +1/-1 remainders, empty dimensions.
    let dims = [0usize, 1, 3, 4, 7, 8, 9, 16, 17, 31];
    let mut rng = Pcg64::seed(1);
    for trial in 0..60 {
        let pick = |rng: &mut Pcg64| dims[(rng.next_u64() % dims.len() as u64) as usize];
        let (m, k, n) = (pick(&mut rng), pick(&mut rng), pick(&mut rng));
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let at = Matrix::randn(k, m, 1.0, &mut rng);
        let bt = Matrix::randn(n, k, 1.0, &mut rng);
        let (s, v) = scalar_vs_auto(|| {
            (
                mat_bits(&matmul(&a, &b)),
                mat_bits(&matmul_at_b(&at, &b)),
                mat_bits(&matmul_a_bt(&a, &bt)),
            )
        });
        assert_eq!(s.0, v.0, "matmul trial={trial} {m}x{k}x{n}");
        assert_eq!(s.1, v.1, "matmul_at_b trial={trial} {m}x{k}x{n}");
        assert_eq!(s.2, v.2, "matmul_a_bt trial={trial} {m}x{k}x{n}");
    }
}

#[test]
fn makhoul_bit_identical_over_widths() {
    // pow2 (radix-2), even non-pow2 (split + Bluestein half), odd
    // (full-complex Bluestein), tiny widths below one complex lane pair.
    let mut rng = Pcg64::seed(2);
    for n in [1usize, 2, 3, 4, 5, 6, 7, 8, 12, 17, 24, 33, 64, 100] {
        let g = Matrix::randn(5, n, 1.0, &mut rng);
        let plan = cached_plan(n);
        let (s, v) = scalar_vs_auto(|| mat_bits(&plan.run(&g)));
        assert_eq!(s, v, "makhoul n={n}");
        let (s, v) = scalar_vs_auto(|| mat_bits(&plan.run_full_complex(&g)));
        assert_eq!(s, v, "makhoul full-complex n={n}");
    }
}

#[test]
fn fft_roundtrip_bit_identical() {
    let mut rng = Pcg64::seed(3);
    for n in [1usize, 2, 5, 8, 13, 16, 27, 64, 100] {
        let x: Vec<Complex> =
            (0..n).map(|_| Complex::new(rng.normal(), rng.normal())).collect();
        let (s, v) = scalar_vs_auto(|| {
            let mut y = x.clone();
            fft_inplace(&mut y);
            complex_bits(&y)
        });
        assert_eq!(s, v, "fft n={n}");
    }
}

#[test]
fn column_norms_and_selection_bit_identical() {
    let mut rng = Pcg64::seed(4);
    for (rows, cols) in [(0usize, 5usize), (1, 1), (3, 3), (7, 4), (9, 5), (6, 23), (11, 32)] {
        let m = Matrix::randn(rows, cols, 1.0, &mut rng);
        let mut acc = vec![0.0f64; cols];
        let (s, v) = scalar_vs_auto(|| {
            m.col_sq_sums_into(&mut acc);
            let sq = f64_bits(&acc);
            m.col_abs_sums_into(&mut acc);
            (
                sq,
                f64_bits(&acc),
                f32_bits(&m.col_l2_norms()),
                f32_bits(&m.col_l1_norms()),
                select_top_columns(&m, cols / 2 + 1, RankNorm::L2),
                select_top_columns(&m, cols / 2 + 1, RankNorm::L1),
            )
        });
        assert_eq!(s, v, "col norms/selection {rows}x{cols}");
    }
}

#[test]
fn all_finite_scan_bit_identical_over_odd_lengths() {
    use fft_subspace::tensor::all_finite;
    // The guard's finite scan is a pure bit-ops reduction, so scalar and
    // vector backends must agree exactly — including poison planted in the
    // vector body, on a lane boundary, and in the scalar tail.
    let mut rng = Pcg64::seed(14);
    for len in [0usize, 1, 5, 7, 8, 9, 15, 16, 17, 31, 64, 70] {
        let clean: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
        let (s, v) = scalar_vs_auto(|| all_finite(&clean));
        assert_eq!(s, v, "clean len={len}");
        assert!(s, "clean data must scan finite (len={len})");
        for poison in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            for at in [0usize, len.saturating_sub(1), len / 2, len.saturating_sub(3)] {
                if len == 0 {
                    continue;
                }
                let mut bad = clean.clone();
                bad[at.min(len - 1)] = poison;
                let (s, v) = scalar_vs_auto(|| all_finite(&bad));
                assert_eq!(s, v, "len={len} poison={poison} at={at}");
                assert!(!s, "poison missed (len={len} at={at})");
            }
        }
        // subnormals, ±0, MAX are finite — the exponent trick must not
        // misclassify the edges of the finite range
        let edges = [f32::MIN_POSITIVE / 4.0, -0.0, 0.0, f32::MAX, f32::MIN];
        let (s, v) = scalar_vs_auto(|| all_finite(&edges));
        assert_eq!(s, v, "edge values");
        assert!(s, "finite edge values misclassified");
    }
}

#[test]
fn fused_adam_kernels_bit_identical_over_odd_lengths() {
    let mut rng = Pcg64::seed(5);
    for len in [0usize, 1, 5, 7, 8, 9, 15, 16, 23, 64, 70] {
        let g: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
        let m0: Vec<f32> = (0..len).map(|_| rng.normal_f32() * 0.1).collect();
        let v0: Vec<f32> = (0..len).map(|_| rng.normal_f32().abs() * 0.01).collect();
        let p0: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
        for step in [1u64, 7, 400] {
            let sc = AdamScalars::new(0.9, 0.999, 1e-8, step);
            // subspace moments kernel
            let (s, v) = scalar_vs_auto(|| {
                let (mut m, mut vv, mut u) = (m0.clone(), v0.clone(), vec![0.0f32; len]);
                adam_moments_into(&mut u, &g, &mut m, &mut vv, &sc);
                (f32_bits(&u), f32_bits(&m), f32_bits(&vv))
            });
            assert_eq!(s, v, "adam_moments len={len} step={step}");
            // dense fused kernel through AdamState (f32 stores in place)
            let (s, v) = scalar_vs_auto(|| {
                let mut st = AdamState::new(1, len);
                st.m.as_f32_mut().unwrap().data.copy_from_slice(&m0);
                st.v.as_f32_mut().unwrap().data.copy_from_slice(&v0);
                let mut p = Matrix::from_vec(1, len, p0.clone());
                let gm = Matrix::from_vec(1, len, g.clone());
                st.update(&mut p, &gm, 0.01, 0.9, 0.999, 1e-8, 0.01, step);
                (mat_bits(&p), mat_bits(st.m.as_f32().unwrap()), mat_bits(st.v.as_f32().unwrap()))
            });
            assert_eq!(s, v, "adam_fused len={len} step={step}");
        }
    }
}

/// The layer zoo shared by the end-to-end tests below: tall, wide
/// (transpose orientation), a Bluestein width, and a dense-path parameter.
fn zoo() -> (Vec<LayerMeta>, Vec<Vec<Matrix>>) {
    let metas = vec![
        LayerMeta::new("wq", 48, 32, ParamKind::Linear),
        LayerMeta::new("w_gate", 32, 48, ParamKind::Linear),
        LayerMeta::new("wk", 40, 24, ParamKind::Linear),
        LayerMeta::new("norm", 1, 32, ParamKind::Norm),
    ];
    let mut rng = Pcg64::seed(6);
    let grad_seq = (0..5)
        .map(|_| {
            metas
                .iter()
                .map(|m| Matrix::randn(m.rows, m.cols, 0.1, &mut rng))
                .collect()
        })
        .collect();
    (metas, grad_seq)
}

fn run_steps(
    kind: &OptimizerKind,
    threads: usize,
    metas: &[LayerMeta],
    grad_seq: &[Vec<Matrix>],
) -> Vec<(usize, usize, Vec<u32>)> {
    let cfg = OptimizerConfig {
        rank: 8,
        update_interval: 2,
        threads: Some(threads),
        ..Default::default()
    };
    let mut opt = build_optimizer(kind, metas, &cfg);
    let mut params: Vec<Matrix> =
        metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
    for grads in grad_seq {
        opt.step(&mut params, grads, 1e-3);
    }
    params.iter().map(mat_bits).collect()
}

#[test]
fn optimizer_steps_bit_identical_end_to_end() {
    // Whole-step integration: every dispatched kernel (orient, Makhoul,
    // selection, matmuls, Newton–Schulz, fused Adam) in one pass, for the
    // paper's two optimizers plus a dense baseline.
    let (metas, grad_seq) = zoo();
    for kind in [OptimizerKind::DctAdamW, OptimizerKind::Trion, OptimizerKind::AdamW] {
        let (s, v) = scalar_vs_auto(|| run_steps(&kind, 1, &metas, &grad_seq));
        assert_eq!(s, v, "{} end-to-end", kind.name());
    }
}

#[test]
fn backend_by_thread_count_matrix_bit_identical() {
    // The full cross matrix the ISSUE pins: {scalar, auto} × {1, 3, 8}
    // pool lanes must all land on the same bits — the SIMD kernels never
    // touch per-element summation order, so the PR-2 thread-determinism
    // contract is backend-independent. Lives in this binary (not
    // parallel_determinism.rs) because it must flip the process-global
    // backend override, which every test here serializes on.
    let (metas, grad_seq) = zoo();
    let _lock = OVERRIDE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = OverrideGuard;
    set_backend_override(None);
    let reference = run_steps(&OptimizerKind::DctAdamW, 1, &metas, &grad_seq);
    for be in [Some(Backend::Scalar), None] {
        set_backend_override(be);
        for threads in [1usize, 3, 8] {
            let got = run_steps(&OptimizerKind::DctAdamW, threads, &metas, &grad_seq);
            assert_eq!(
                got, reference,
                "dct-adamw diverged: backend={be:?} threads={threads}"
            );
        }
        set_backend_override(None);
    }
}

// ---- typed-storage pack/unpack kernels (tensor::store) -----------------

#[test]
fn storage_pack_kernels_bit_identical_over_odd_lengths() {
    use fft_subspace::tensor::store::{
        bf16_add_into, bf16_pack_into, bf16_unpack_into, q8_add_into,
        q8_dequantize_into, q8_quantize_into,
    };
    let mut rng = Pcg64::seed(11);
    for len in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31, 64, 70] {
        let mut src: Vec<f32> = (0..len).map(|_| rng.normal_f32() * 10.0).collect();
        // salt the edge cases into random lanes (vector body AND tail)
        for (i, v) in [f32::NAN, f32::INFINITY, -0.0, f32::MIN_POSITIVE / 4.0]
            .iter()
            .enumerate()
        {
            if len > i {
                let at = (rng.next_u64() as usize) % len;
                src[at] = *v;
            }
        }
        let base: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
        let scale = 0.173f32;

        let (s, v) = scalar_vs_auto(|| {
            let mut packed = vec![0u16; len];
            bf16_pack_into(&mut packed, &src);
            let mut unpacked = vec![0.0f32; len];
            bf16_unpack_into(&mut unpacked, &packed);
            let mut added = base.clone();
            bf16_add_into(&mut added, &packed);
            let mut q = vec![0i8; len];
            q8_quantize_into(&mut q, &src, scale);
            let mut deq = vec![0.0f32; len];
            q8_dequantize_into(&mut deq, &q, scale);
            let mut qadd = base.clone();
            q8_add_into(&mut qadd, &q, scale);
            (packed, f32_bits(&unpacked), f32_bits(&added), q, f32_bits(&deq), f32_bits(&qadd))
        });
        assert_eq!(s, v, "len={len}");
    }
}

#[test]
fn bf16_pack_is_round_to_nearest_even() {
    use fft_subspace::tensor::bf16::{bf16_bits_to_f32, f32_to_bf16_bits};
    use fft_subspace::tensor::store::bf16_pack_into;
    // Midpoint values: f32 bit patterns exactly halfway between two
    // adjacent bf16 values (low 16 bits = 0x8000) must round to the EVEN
    // bf16 mantissa, in both the vector body and the scalar tail.
    let mids: Vec<f32> = (0..9)
        .map(|i| {
            let hi = 0x3F80u32 + i; // 1.0 + i·2⁻⁷ region, alternating parity
            f32::from_bits((hi << 16) | 0x8000)
        })
        .collect();
    let mut packed = vec![0u16; mids.len()];
    bf16_pack_into(&mut packed, &mids);
    for (i, (&p, &m)) in packed.iter().zip(mids.iter()).enumerate() {
        assert_eq!(p, f32_to_bf16_bits(m), "lane {i}");
        // round-to-nearest-even: the result's LSB is always 0 on exact ties
        assert_eq!(p & 1, 0, "lane {i}: tie did not round to even ({p:#06x})");
        // and the rounding error is exactly half a ULP of the bf16 grid
        let back = bf16_bits_to_f32(p);
        let ulp = f32::from_bits(((p as u32) << 16) & 0x7F80_0000) * (1.0 / 128.0);
        assert!((back - m).abs() <= ulp * 0.5 + f32::EPSILON, "lane {i}");
    }
}

#[test]
fn q8_roundtrip_error_bounded_by_half_step() {
    use fft_subspace::tensor::{Matrix as M, StateDtype, StateStore};
    let mut rng = Pcg64::seed(12);
    for _ in 0..20 {
        let m = M::randn(7, 9, (rng.next_f32() + 0.1) * 4.0, &mut rng);
        let mut st = StateStore::zeros(StateDtype::Q8, 7, 9);
        st.store_from(&m);
        let back = st.to_matrix();
        let step = m.abs_max() / 127.0 + 1e-12;
        assert!(
            back.max_abs_diff(&m) <= step * 0.5 + 1e-7,
            "err {} > half-step {}",
            back.max_abs_diff(&m),
            step * 0.5
        );
    }
}

#[test]
fn engine_step_bit_identical_across_backends_with_typed_state() {
    use fft_subspace::optim::OptimizerSpec;
    use fft_subspace::tensor::StateDtype;
    // the full DCT-AdamW engine step with bf16 stores: pack/unpack kernels
    // sit on the hot path, so scalar and vector backends must agree on the
    // entire trajectory
    let metas = vec![
        LayerMeta::new("w", 20, 12, ParamKind::Linear),
        LayerMeta::new("norm", 1, 12, ParamKind::Norm),
    ];
    let mut rng = Pcg64::seed(13);
    let grads: Vec<Vec<Matrix>> = (0..4)
        .map(|_| {
            metas
                .iter()
                .map(|m| Matrix::randn(m.rows, m.cols, 0.1, &mut rng))
                .collect()
        })
        .collect();
    let (s, v) = scalar_vs_auto(|| {
        let mut opt = OptimizerSpec::dct_adamw(3)
            .state_dtype(StateDtype::Bf16)
            .threads(Some(1))
            .build(&metas);
        let mut params: Vec<Matrix> =
            metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
        for g in &grads {
            opt.step(&mut params, g, 1e-2);
        }
        params.iter().map(mat_bits).collect::<Vec<_>>()
    });
    assert_eq!(s, v);
}
