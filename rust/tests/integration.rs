//! Cross-layer integration tests: full trainer runs through PJRT, AOT vs
//! native optimizer equivalence over multiple steps, DDP + ZeRO wiring,
//! checkpoint round-trips, and the fine-tuning accuracy pipeline.
//!
//! These need `make artifacts` to have run (CI order: artifacts → test).

use fft_subspace::data::TaskCorpus;
use fft_subspace::optim::OptimizerKind;
use fft_subspace::projection::{ProjectionKind, RankNorm};
use fft_subspace::runtime::{Manifest, Runtime};
use fft_subspace::train::finetune::Finetuner;
use fft_subspace::train::{checkpoint, TrainConfig, Trainer};

/// These tests need `make artifacts` AND a real PJRT plugin. When either is
/// missing (e.g. the offline stub `xla` crate) they skip instead of failing;
/// CI environments with the full stack run them end to end. The shared
/// skip-or-require logic lives in `fft_subspace::runtime::testing`.
fn setup() -> Option<(Manifest, Runtime)> {
    fft_subspace::runtime::testing::pjrt_setup("integration test")
}

fn out_dir() -> String {
    std::env::temp_dir()
        .join("fft_subspace_itest_runs")
        .to_string_lossy()
        .into_owned()
}

fn base_cfg(optimizer: OptimizerKind, steps: usize) -> TrainConfig {
    let mut cfg = TrainConfig {
        preset: "nano".into(),
        optimizer,
        steps,
        workers: 2,
        eval_every: 0,
        eval_batches: 2,
        corpus_tokens: 100_000,
        out_dir: out_dir(),
        ..Default::default()
    };
    cfg.opt.rank = 16;
    cfg
}

#[test]
fn trainer_learns_with_trion() {
    let (m, rt) = match setup() {
        Some(x) => x,
        None => return,
    };
    let mut cfg = base_cfg(OptimizerKind::Trion, 40);
    cfg.run_name = "itest_trion".into();
    let mut tr = Trainer::new(&m, &rt, cfg).unwrap();
    let spec_vocab_loss = (tr.spec.vocab as f64).ln(); // ≈ 5.55
    let sum = tr.run(&m, &rt).unwrap();
    assert!(
        sum.final_train_loss < spec_vocab_loss - 0.4,
        "no learning: {} -> {}",
        spec_vocab_loss,
        sum.final_train_loss
    );
    assert!(sum.val_loss.is_finite() && sum.val_ppl > 1.0);
    // metrics file exists and has records
    let text = std::fs::read_to_string(&sum.metrics_path).unwrap();
    assert!(text.lines().count() >= 5);
}

#[test]
fn every_optimizer_survives_a_short_run() {
    let (m, rt) = match setup() {
        Some(x) => x,
        None => return,
    };
    for kind in [
        OptimizerKind::AdamW,
        OptimizerKind::Muon,
        OptimizerKind::Dion,
        OptimizerKind::Trion,
        OptimizerKind::GaLore,
        OptimizerKind::LdAdamW,
        OptimizerKind::DctAdamW,
        OptimizerKind::Frugal,
        OptimizerKind::Fira,
    ] {
        let mut cfg = base_cfg(kind.clone(), 6);
        cfg.run_name = format!("itest_all_{}", kind.name());
        cfg.lr = 1e-3;
        let mut tr = Trainer::new(&m, &rt, cfg).unwrap();
        let sum = tr.run(&m, &rt).unwrap();
        assert!(
            sum.final_train_loss.is_finite(),
            "{}: loss diverged",
            kind.name()
        );
        assert!(sum.optimizer_state_bytes > 0);
    }
}

#[test]
fn aot_and_native_trion_train_identically() {
    // The strongest three-layer check: a full multi-step *training* run
    // (PJRT gradients, DDP all-reduce, ZeRO accounting) with the optimizer
    // running through the AOT pallas-kernel graphs must match the rust-
    // native optimizer to float tolerance on the final parameters.
    let (m, rt) = match setup() {
        Some(x) => x,
        None => return,
    };
    let mut final_losses = Vec::new();
    for use_aot in [false, true] {
        let mut cfg = base_cfg(OptimizerKind::Trion, 8);
        cfg.run_name = format!("itest_aot_{use_aot}");
        cfg.use_aot_optimizer = use_aot;
        // match the lowered graphs: matmul similarities + L2 ranking
        cfg.opt.projection = ProjectionKind::Dct { norm: RankNorm::L2, use_makhoul: false };
        cfg.opt.rank = 32;
        cfg.opt.mu = 0.95;
        let mut tr = Trainer::new(&m, &rt, cfg).unwrap();
        let sum = tr.run(&m, &rt).unwrap();
        final_losses.push(sum.final_train_loss);
    }
    let diff = (final_losses[0] - final_losses[1]).abs();
    assert!(
        diff < 5e-3,
        "native {} vs aot {} (diff {diff})",
        final_losses[0],
        final_losses[1]
    );
}

#[test]
fn worker_count_changes_only_throughput_not_correctness() {
    // More workers = bigger effective batch from disjoint shards; loss must
    // stay finite and broadly comparable, comm bytes must grow.
    let (m, rt) = match setup() {
        Some(x) => x,
        None => return,
    };
    let mut comm = Vec::new();
    for workers in [1usize, 4] {
        let mut cfg = base_cfg(OptimizerKind::Trion, 10);
        cfg.workers = workers;
        cfg.run_name = format!("itest_w{workers}");
        let mut tr = Trainer::new(&m, &rt, cfg).unwrap();
        let sum = tr.run(&m, &rt).unwrap();
        assert!(sum.final_train_loss.is_finite());
        comm.push(sum.comm_bytes);
    }
    assert_eq!(comm[0], 0, "single worker should move no bytes");
    assert!(comm[1] > 0);
}

#[test]
fn checkpoint_roundtrip_through_finetune() {
    let (m, rt) = match setup() {
        Some(x) => x,
        None => return,
    };
    let mut cfg = base_cfg(OptimizerKind::AdamW, 12);
    cfg.run_name = "itest_ckpt_pretrain".into();
    cfg.lr = 3e-3;
    let mut tr = Trainer::new(&m, &rt, cfg).unwrap();
    tr.run(&m, &rt).unwrap();
    let path = std::env::temp_dir().join("fft_subspace_itest.ckpt");
    checkpoint::save(&path, &tr.params).unwrap();
    let loaded = checkpoint::load(&path).unwrap();
    assert_eq!(loaded.len(), tr.params.len());

    // fine-tune from the checkpoint and get a real accuracy number
    let mut ft_cfg = base_cfg(OptimizerKind::DctAdamW, 15);
    ft_cfg.lr = 1e-3;
    let mut ft = Finetuner::new(&m, &rt, ft_cfg, Some(loaded)).unwrap();
    let sum = ft.run(&m, &rt).unwrap();
    assert!(sum.final_train_loss.is_finite());
    assert!((0.0..=1.0).contains(&sum.accuracy));
}

#[test]
fn task_corpus_oracle_matches_predict_artifact_shape() {
    // The predict artifact must emit (B, S) argmax positions usable by the
    // exact-match scorer.
    let (m, rt) = match setup() {
        Some(x) => x,
        None => return,
    };
    let spec = m.model_spec("nano").unwrap();
    let exe = rt.load(m.find("predict_nano").unwrap()).unwrap();
    let corpus = TaskCorpus::generate(4, 4, spec.seq_len, 0);
    let params = fft_subspace::train::trainer::init_params(&spec, 0);
    let mut data = Vec::new();
    for ex in corpus.test.iter().take(spec.batch_per_worker) {
        data.extend(ex.tokens.iter().map(|&t| t as i32));
    }
    while data.len() < spec.batch_per_worker * spec.seq_len {
        data.extend(corpus.test[0].tokens.iter().map(|&t| t as i32));
    }
    let mut inputs: Vec<fft_subspace::runtime::client::Value> = params
        .iter()
        .map(|p| fft_subspace::runtime::client::Value::F32(p.clone()))
        .collect();
    inputs.push(fft_subspace::runtime::client::Value::tokens(
        data,
        vec![spec.batch_per_worker, spec.seq_len],
    ));
    let outs = exe.run(&inputs).unwrap();
    assert_eq!(
        outs.values[0].shape(),
        (spec.batch_per_worker, spec.seq_len)
    );
    // argmax values are valid token ids
    assert!(outs.values[0].data.iter().all(|&v| v >= 0.0 && v < spec.vocab as f32));
}
