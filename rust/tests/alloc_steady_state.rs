//! Zero-allocation regression proof for the optimizer hot path.
//!
//! A counting `#[global_allocator]` wraps the system allocator; after a
//! warmup long enough to fill every workspace pool (several full refresh
//! cycles), counting is switched on and a window of steady-state
//! engine-backed optimizer steps must perform exactly **zero** heap
//! allocations — for **all six** low-rank presets (DctAdamW, Trion, GaLore,
//! Fira, Frugal, LdAdamW), covering the project-only and subspace-refresh
//! paths, tall/wide/Bluestein-width layers, Q8/f32 error feedback, the
//! workspace-backed Newton–Schulz orthogonalization, the workspace-backed
//! block-power refresh (`qr_q_into`) and — since the typed-storage PR —
//! GaLore's Jacobi SVD refresh (`svd_right_vectors_into`), which closed the
//! last refresh-path carve-out. Each preset's proof runs twice:
//! sequentially (1 thread lane) and through the parallel
//! `step_layers_parallel` path (3 lanes), because the counter is global
//! across threads — worker-side allocations would be caught too. The
//! parallel path stays clean because the pool dispatch boxes nothing and
//! chunk `k` is permanently bound to workspace shard `k` / its own pooled
//! FFT scratch (warmed during the uncounted warmup window). The SIMD
//! dispatch layer is exercised implicitly (every kernel routes through it)
//! and is allocation-free by construction: one atomic load, no boxing.
//! Every counted step also runs the numerical-health guard
//! (`StepGuard::check` → the `all_finite` SIMD scan over all gradients),
//! pinning that a guarded training step costs zero allocations too.
//!
//! The sweep also runs under two state dtypes (`f32` and `bf16` — plus
//! whatever `FFT_SUBSPACE_STATE_DTYPE` adds in `make test-matrix`): non-f32
//! stores stage their de/quantization through `Workspace` scratch, so the
//! typed-storage layer must not cost a single steady-state allocation
//! either.
//!
//! Since the observability PR the whole sweep additionally runs under all
//! three telemetry tiers (`obs=off|counters|trace`): counters are static
//! atomics and trace spans write into rings the engine preallocated at
//! build time, so full telemetry must not cost a single steady-state
//! allocation either.
//!
//! Since the step-plan PR the sweep also covers both execution plans
//! (`step-plan=fused|interpreted`): the fused shape-batched group programs
//! own their staging/similarity/low-rank slabs (allocated at plan build)
//! and refill their `SendPtr` scatter tables in place, so a fused step —
//! batched refresh included — must be exactly as allocation-free as the
//! interpreted per-layer loop it replaces. The zoo repeats shapes so the
//! plan forms multi-layer groups and the batched kernels genuinely stack.
//!
//! This file is its own test binary (integration test), so the global
//! allocator and the single `#[test]` share the process without
//! interference from the rest of the suite.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use fft_subspace::coordinator::{
    build_grad_sync, CommMode, CommModel, Communicator, WireFormat,
};
use fft_subspace::obs::{self, ObsTier};
use fft_subspace::optim::{
    build_optimizer, LayerMeta, Optimizer, OptimizerConfig, OptimizerKind, ParamKind,
    StepPlanMode,
};
use fft_subspace::tensor::{Matrix, StateDtype};
use fft_subspace::train::{GuardPolicy, StepGuard};
use fft_subspace::util::Pcg64;

struct CountingAlloc;

static ENABLED: AtomicBool = AtomicBool::new(false);
static ALLOC_CALLS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if ENABLED.load(Ordering::Relaxed) {
            ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if ENABLED.load(Ordering::Relaxed) {
            ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        }
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if ENABLED.load(Ordering::Relaxed) {
            ALLOC_CALLS.fetch_add(1, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_steps_are_allocation_free() {
    // Layer zoo: tall, wide (transpose orientation), a width whose Makhoul
    // half-plan is non-power-of-two (24 → 12-point Bluestein), and a dense
    // AdamW-path norm parameter. The tall and wide shapes repeat so the
    // fused step plan forms multi-layer groups (stacked batched kernels),
    // not just degenerate singletons.
    let metas = vec![
        LayerMeta::new("wq", 48, 32, ParamKind::Linear),
        LayerMeta::new("w_gate", 32, 48, ParamKind::Linear),
        LayerMeta::new("wk", 40, 24, ParamKind::Linear),
        LayerMeta::new("norm", 1, 32, ParamKind::Norm),
        LayerMeta::new("wq2", 48, 32, ParamKind::Linear),
        LayerMeta::new("w_gate2", 32, 48, ParamKind::Linear),
    ];
    let mut rng = Pcg64::seed(0);
    let grads: Vec<Matrix> = metas
        .iter()
        .map(|m| Matrix::randn(m.rows, m.cols, 0.1, &mut rng))
        .collect();

    // f32 (the bit-exact default) + bf16 (typed-storage staging); the
    // test-matrix env knob can swap the non-f32 point to q8.
    let mut dtypes = vec![StateDtype::F32, StateDtype::Bf16];
    if let Some(d) = StateDtype::from_env() {
        if !dtypes.contains(&d) {
            dtypes.push(d);
        }
    }

    // One proof per (preset, dtype, step plan, execution mode): sequential
    // (1 lane) and the parallel path (3 lanes, 6 layers → 2 per chunk). DctAdamW pins the vectorized project/refresh/EF
    // path, Trion the workspace-backed Newton–Schulz, LdAdamW the
    // workspace-backed block-power refresh (refresh every step), Fira/
    // Frugal the residual policies over the DCT source, GaLore the
    // workspace-backed Jacobi SVD refresh (update_interval=4 puts two
    // refreshes inside the counted window — the carve-out the ROADMAP used
    // to list is closed). Pool threads spawn at optimizer construction —
    // before counting. (One #[test] for everything: the counter is
    // process-global, so concurrently-running tests would pollute each
    // other's windows.)
    // Every proof runs under all three observability tiers (PR 7): the
    // zero-allocation contract holds with telemetry fully on. `counters`
    // adds relaxed atomic increments (no heap); `trace` adds span pushes
    // into the engine's preallocated event rings — the tier must be
    // active at *build* time, because the engine sizes its rings then.
    // Nobody drains the rings here, so they fill and start dropping
    // (a Cell increment, not a realloc) — exactly the contract.
    for tier in [ObsTier::Off, ObsTier::Counters, ObsTier::Trace] {
        obs::set_tier(tier);
        for kind in [
            OptimizerKind::DctAdamW,
            OptimizerKind::Trion,
            OptimizerKind::GaLore,
            OptimizerKind::Fira,
            OptimizerKind::Frugal,
            OptimizerKind::LdAdamW,
        ] {
            for &state_dtype in &dtypes {
                for step_plan in [StepPlanMode::Fused, StepPlanMode::Interpreted] {
                for threads in [1usize, 3] {
                    let cfg = OptimizerConfig {
                        rank: 8,
                        threads: Some(threads),
                        state_dtype,
                        step_plan,
                        // exercise refresh AND project-only steps inside the
                        // counted window for every preset
                        update_interval: 4,
                        ..Default::default()
                    };
                    let mut opt = build_optimizer(&kind, &metas, &cfg);
                    let mut params: Vec<Matrix> = metas
                        .iter()
                        .map(|m| Matrix::zeros(m.rows, m.cols))
                        .collect();
                    // The numerical-health guard rides the hot path when
                    // enabled (`guard=skip|rollback`), so a guarded step must
                    // be allocation-free too: the finite scan is a pure SIMD
                    // reduction and the EMA update is two scalar ops.
                    let mut guard = StepGuard::new(GuardPolicy::Skip, 2.0);

                    // Warmup: several full refresh cycles fill the per-shard
                    // workspace pools, the shared plan caches and the per-plan
                    // scratch pools up to their parallel high-water mark.
                    for _ in 0..12 {
                        assert!(guard.check(1.0, &grads).is_healthy());
                        opt.step(&mut params, &grads, 1e-3);
                    }

                    ALLOC_CALLS.store(0, Ordering::SeqCst);
                    ENABLED.store(true, Ordering::SeqCst);
                    for _ in 0..8 {
                        assert!(guard.check(1.0, &grads).is_healthy());
                        opt.step(&mut params, &grads, 1e-3);
                    }
                    ENABLED.store(false, Ordering::SeqCst);

                    let allocs = ALLOC_CALLS.load(Ordering::SeqCst);
                    assert_eq!(
                        allocs,
                        0,
                        "steady-state {} steps (threads={threads}, \
                         state-dtype={}, step-plan={}, obs={}) performed \
                         {allocs} heap allocations (expected zero — a \
                         workspace buffer is being dropped or resized, the \
                         pool dispatch allocates, a fused group program \
                         resizes a staging slab, or a telemetry hook \
                         heap-allocates)",
                        kind.name(),
                        state_dtype.name(),
                        step_plan.name(),
                        tier.name()
                    );

                    // sanity: the optimizer actually did work in the counted
                    // window
                    assert!(params[0].fro_norm() > 0.0);
                }
                }
            }
        }
    }
    obs::set_tier(ObsTier::Off);

    // Same process, same counter (a second #[test] could run concurrently
    // and pollute the window): steady subspace-compressed gradient sync —
    // q8 wire included — is allocation-free too.
    for wire in [WireFormat::F32, WireFormat::Q8] {
        steady_compressed_sync_is_allocation_free(wire);
    }
}

/// Drive full synchronized steps (`SubspaceSync::reduce` → `opt.step` →
/// `after_step`) at world=4 and count a refresh-free window: coefficient
/// slabs, EF stores, ring scratch, wire scratch and the delivery vector
/// are all sized during warmup, so steady compressed steps must not
/// allocate — for both wire formats. Worker gradients are recycled
/// (refilled in place from a pregenerated set; the delivered matrices
/// return to worker 0's slots) because the real trainer owns fresh
/// buffers each step — here they'd count as harness noise.
fn steady_compressed_sync_is_allocation_free(wire: WireFormat) {
    let metas = vec![
        LayerMeta::new("wq", 48, 32, ParamKind::Linear),
        LayerMeta::new("w_gate", 32, 48, ParamKind::Linear), // transpose path
        LayerMeta::new("wk", 40, 24, ParamKind::Linear),
        LayerMeta::new("norm", 1, 32, ParamKind::Norm), // dense path
    ];
    let world = 4usize;
    // refresh cadence far past the counted window (steps 13–20): the
    // refresh boundary may allocate (it pipelines through a scope when a
    // pool is attached); the steady-state contract is about compressed
    // steps
    let cfg = OptimizerConfig {
        rank: 8,
        update_interval: 40,
        threads: Some(1),
        ..Default::default()
    };
    let mut opt = build_optimizer(&OptimizerKind::DctAdamW, &metas, &cfg);
    let mut sync = build_grad_sync(CommMode::Subspace, wire, world, &metas);
    let mut comm = Communicator::new(world, CommModel::default());
    let mut params: Vec<Matrix> =
        metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
    let mut rng = Pcg64::seed(9);
    let pregen: Vec<Vec<Matrix>> = (0..world)
        .map(|_| {
            metas
                .iter()
                .map(|m| Matrix::randn(m.rows, m.cols, 0.1, &mut rng))
                .collect()
        })
        .collect();
    let mut wg: Vec<Vec<Matrix>> = pregen.clone();
    let mut g: Vec<Matrix> = Vec::new();
    let mut step_one = |wg: &mut Vec<Vec<Matrix>>, g: &mut Vec<Matrix>| {
        for (w, src) in pregen.iter().enumerate() {
            for (pi, m) in src.iter().enumerate() {
                wg[w][pi].copy_from(m);
            }
        }
        sync.reduce(wg, opt.as_ref(), &mut comm, g);
        opt.step(&mut params, g, 1e-3);
        sync.after_step(opt.as_ref(), &mut comm);
        // the delivered matrices are worker 0's consumed buffers — hand
        // them back so the next refill finds full-size slots
        for (pi, m) in g.drain(..).enumerate() {
            wg[0][pi] = m;
        }
    };
    // warmup covers the t=1 refresh plus enough compressed steps to fill
    // every pool (workspace, coeff slabs, ring + wire scratch, `g`)
    for _ in 0..12 {
        step_one(&mut wg, &mut g);
    }
    ALLOC_CALLS.store(0, Ordering::SeqCst);
    ENABLED.store(true, Ordering::SeqCst);
    for _ in 0..8 {
        step_one(&mut wg, &mut g);
    }
    ENABLED.store(false, Ordering::SeqCst);
    let allocs = ALLOC_CALLS.load(Ordering::SeqCst);
    assert_eq!(
        allocs, 0,
        "steady compressed sync steps (wire={}) performed {allocs} heap \
         allocations (expected zero — a sync scratch buffer is being \
         dropped or resized, or the wire codec allocates per block)",
        wire.name()
    );
    assert!(params[0].fro_norm() > 0.0);
}
