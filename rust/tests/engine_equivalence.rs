//! Preset-equivalence contract: each of the six published low-rank methods
//! built through the composable engine (`OptimizerSpec` presets, what
//! `build_optimizer` now returns) must produce **bit-identical** parameter
//! trajectories to the pre-engine hand-written optimizers.
//!
//! The reference implementations below are frozen copies of the legacy
//! per-layer step loops (from the deleted `dct_adamw.rs`, `trion.rs`,
//! `galore.rs`, `fira.rs`, `frugal.rs`, `ldadamw.rs`), written against the
//! *allocating* projection/tensor APIs — which are bit-identical to the
//! `_into` kernels the engine uses (property-pinned in `projection/mod.rs`
//! and `tensor/ops.rs`), so any trajectory divergence is an engine policy
//! bug, not numerics noise. Comparisons are on raw `to_bits` patterns over
//! ≥ 12 steps on a mixed tall/wide/square/Bluestein-width/dense layer zoo.

use std::collections::BTreeMap;
use std::sync::Arc;

use fft_subspace::optim::common::{orient, shape_factor, AdamState};
use fft_subspace::optim::error_feedback::EfBuffer;
use fft_subspace::optim::{
    adam_moments_into, build_optimizer, AdamScalars, EfMode, LayerMeta, Optimizer,
    OptimizerConfig, OptimizerKind, ParamKind,
};
use fft_subspace::linalg::newton_schulz;
use fft_subspace::projection::{
    BlockPower, DctSelect, Projection, ProjectionKind, RankNorm, SharedDct,
};
use fft_subspace::tensor::{matmul, Matrix};
use fft_subspace::train::TrainConfig;
use fft_subspace::util::Pcg64;

/// Mixed layer zoo: tall, wide (transpose orientation), square, a width
/// whose Makhoul half-plan needs Bluestein (24), plus dense-path params.
fn layer_zoo() -> Vec<LayerMeta> {
    vec![
        LayerMeta::new("wq", 48, 32, ParamKind::Linear),
        LayerMeta::new("w_gate", 32, 48, ParamKind::Linear),
        LayerMeta::new("wk", 40, 24, ParamKind::Linear),
        LayerMeta::new("wv", 32, 32, ParamKind::Linear),
        LayerMeta::new("norm", 1, 32, ParamKind::Norm),
        LayerMeta::new("embed", 64, 32, ParamKind::Embed),
    ]
}

fn grad_seq(metas: &[LayerMeta], steps: usize, seed: u64) -> Vec<Vec<Matrix>> {
    let mut rng = Pcg64::seed(seed);
    (0..steps)
        .map(|_| {
            metas
                .iter()
                .map(|m| Matrix::randn(m.rows, m.cols, 0.1, &mut rng))
                .collect()
        })
        .collect()
}

fn bits(m: &Matrix) -> Vec<u32> {
    m.data.iter().map(|v| v.to_bits()).collect()
}

fn shared_dct(metas: &[LayerMeta]) -> BTreeMap<usize, Arc<SharedDct>> {
    let mut map = BTreeMap::new();
    for m in metas {
        if m.kind.low_rank_eligible() {
            let (_, c) = m.oriented();
            map.entry(c).or_insert_with(|| Arc::new(SharedDct::new(c)));
        }
    }
    map
}

fn dct_norm(cfg: &OptimizerConfig) -> (RankNorm, bool) {
    match &cfg.projection {
        ProjectionKind::Dct { norm, use_makhoul } => (*norm, *use_makhoul),
        _ => (RankNorm::L2, true),
    }
}

/// Frozen pre-refactor fixed-basis rotation, verbatim from the deleted
/// `dct_adamw.rs` (modulo its workspace staging, which only affected
/// buffer reuse, not values) — deliberately NOT the engine's rewritten
/// `rotate_fixed_basis`, so the harness shares no rotation kernel with the
/// code under test.
fn legacy_rotate_fixed_basis(m: &Matrix, idx_prev: &[usize], idx_crt: &[usize]) -> Matrix {
    debug_assert_eq!(m.cols, idx_prev.len());
    let mut out = Matrix::zeros(m.rows, idx_crt.len());
    // Both index lists are sorted ascending — merge them.
    let (mut a, mut b) = (0usize, 0usize);
    while a < idx_prev.len() && b < idx_crt.len() {
        match idx_prev[a].cmp(&idx_crt[b]) {
            std::cmp::Ordering::Less => a += 1,
            std::cmp::Ordering::Greater => b += 1,
            std::cmp::Ordering::Equal => {
                for i in 0..m.rows {
                    out.data[i * idx_crt.len() + b] = m.data[i * m.cols + a];
                }
                a += 1;
                b += 1;
            }
        }
    }
    out
}

/// A frozen legacy step loop (sequential, allocating).
trait LegacyOptimizer {
    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], lr: f32);
    fn errors(&self) -> Option<&BTreeMap<String, f64>> {
        None
    }
}

// ---- legacy DCT-AdamW (Algorithms 2–3) ----------------------------------

enum DctLayer {
    LowRank {
        select: DctSelect,
        idx_prev: Vec<usize>,
        m: Matrix,
        v: Matrix,
        ef: EfBuffer,
        first: bool,
    },
    Adam(AdamState),
}

struct LegacyDctAdamW {
    metas: Vec<LayerMeta>,
    states: Vec<DctLayer>,
    cfg: OptimizerConfig,
    step: u64,
}

impl LegacyDctAdamW {
    fn new(metas: &[LayerMeta], cfg: &OptimizerConfig) -> Self {
        let shared = shared_dct(metas);
        let (norm, mk) = dct_norm(cfg);
        let states = metas
            .iter()
            .map(|meta| {
                if meta.kind.low_rank_eligible() {
                    let (rr, cc) = meta.oriented();
                    let r = cfg.rank.min(cc);
                    DctLayer::LowRank {
                        select: DctSelect::new(shared[&cc].clone(), r, norm, mk),
                        idx_prev: (0..r).collect(),
                        m: Matrix::zeros(rr, r),
                        v: Matrix::zeros(rr, r),
                        ef: EfBuffer::new(cfg.ef_mode, rr, cc),
                        first: true,
                    }
                } else {
                    DctLayer::Adam(AdamState::new(meta.rows, meta.cols))
                }
            })
            .collect();
        LegacyDctAdamW { metas: metas.to_vec(), states, cfg: cfg.clone(), step: 0 }
    }
}

impl LegacyOptimizer for LegacyDctAdamW {
    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], lr: f32) {
        self.step += 1;
        let t = self.step;
        let c = &self.cfg;
        let refresh = t == 1 || t % c.update_interval.max(1) as u64 == 0;
        for i in 0..params.len() {
            let meta = &self.metas[i];
            match &mut self.states[i] {
                DctLayer::Adam(st) => st.update(
                    &mut params[i], &grads[i], lr, c.beta1, c.beta2, c.eps,
                    c.weight_decay, t,
                ),
                DctLayer::LowRank { select, idx_prev, m, v, ef, first } => {
                    let mut g = orient(meta, &grads[i]);
                    ef.add_into(&mut g);
                    let g_low = if refresh {
                        idx_prev.clear();
                        idx_prev.extend_from_slice(select.indices());
                        let low = select.refresh_and_project(&g);
                        if !*first {
                            *m = legacy_rotate_fixed_basis(m, idx_prev, select.indices());
                            *v = legacy_rotate_fixed_basis(v, idx_prev, select.indices());
                            for x in &mut v.data {
                                *x = x.abs();
                            }
                        }
                        *first = false;
                        low
                    } else {
                        select.project(&g)
                    };
                    let mut back = select.back(&g_low);
                    back.sub_from(&g);
                    ef.store(&back);
                    let sc = AdamScalars::new(c.beta1, c.beta2, c.eps, t);
                    let mut u_low = Matrix::zeros(g_low.rows, g_low.cols);
                    adam_moments_into(
                        &mut u_low.data, &g_low.data, &mut m.data, &mut v.data, &sc,
                    );
                    let u = select.back(&u_low);
                    params[i].scale(1.0 - lr * c.weight_decay);
                    if meta.needs_transpose() {
                        params[i].axpy_t(-lr, &u);
                    } else {
                        params[i].axpy(-lr, &u);
                    }
                }
            }
        }
    }
}

// ---- legacy Trion (Algorithm 1) -----------------------------------------

enum TrionLayer {
    LowRank { momentum: Matrix, select: DctSelect },
    Adam(AdamState),
}

struct LegacyTrion {
    metas: Vec<LayerMeta>,
    states: Vec<TrionLayer>,
    cfg: OptimizerConfig,
    step: u64,
    errors: BTreeMap<String, f64>,
}

impl LegacyTrion {
    fn new(metas: &[LayerMeta], cfg: &OptimizerConfig) -> Self {
        let shared = shared_dct(metas);
        let (norm, mk) = dct_norm(cfg);
        let states = metas
            .iter()
            .map(|meta| {
                if meta.kind.low_rank_eligible() {
                    let (rr, cc) = meta.oriented();
                    let select =
                        DctSelect::new(shared[&cc].clone(), cfg.rank.min(cc), norm, mk);
                    TrionLayer::LowRank { momentum: Matrix::zeros(rr, cc), select }
                } else {
                    TrionLayer::Adam(AdamState::new(meta.rows, meta.cols))
                }
            })
            .collect();
        LegacyTrion {
            metas: metas.to_vec(),
            states,
            cfg: cfg.clone(),
            step: 0,
            errors: BTreeMap::new(),
        }
    }
}

impl LegacyOptimizer for LegacyTrion {
    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], lr: f32) {
        self.step += 1;
        let t = self.step;
        let c = &self.cfg;
        for i in 0..params.len() {
            let meta = &self.metas[i];
            match &mut self.states[i] {
                TrionLayer::Adam(st) => st.update(
                    &mut params[i], &grads[i], lr, c.beta1, c.beta2, c.eps, 0.0, t,
                ),
                TrionLayer::LowRank { momentum, select } => {
                    let (rr, cc) = meta.oriented();
                    if meta.needs_transpose() {
                        momentum.axpy_t(1.0, &grads[i]);
                    } else {
                        momentum.axpy(1.0, &grads[i]);
                    }
                    let b_low = select.refresh_and_project(momentum);
                    let back = select.back(&b_low);
                    momentum.axpy(-(1.0 - c.mu), &back);
                    let o_low = newton_schulz(&b_low, c.ns_steps);
                    let o = select.back(&o_low);
                    if c.instrument {
                        let mut b_now = momentum.clone();
                        b_now.axpy(1.0 - c.mu, &back);
                        b_now.axpy(-1.0, &o);
                        self.errors.insert(meta.name.clone(), b_now.fro_norm());
                    }
                    params[i].scale(1.0 - lr * c.weight_decay);
                    let scale = -lr * shape_factor(rr, cc);
                    if meta.needs_transpose() {
                        params[i].axpy_t(scale, &o);
                    } else {
                        params[i].axpy(scale, &o);
                    }
                }
            }
        }
    }

    fn errors(&self) -> Option<&BTreeMap<String, f64>> {
        if self.cfg.instrument {
            Some(&self.errors)
        } else {
            None
        }
    }
}

// ---- legacy GaLore / FIRA / FRUGAL (projection-pluggable AdamW family) --

#[derive(Clone, Copy, PartialEq)]
enum ResidualFlavor {
    Discard,  // GaLore
    FiraNorm, // FIRA
    Sign,     // FRUGAL (sign_lr_scale = 1.0)
}

enum ProjLayer {
    LowRank { proj: Box<dyn Projection>, m: Matrix, v: Matrix },
    Adam(AdamState),
}

struct LegacyProjAdamW {
    metas: Vec<LayerMeta>,
    states: Vec<ProjLayer>,
    cfg: OptimizerConfig,
    flavor: ResidualFlavor,
    step: u64,
}

impl LegacyProjAdamW {
    /// `seed_shift`: GaLore used 8, FRUGAL 4, FIRA 12.
    fn new(
        metas: &[LayerMeta],
        cfg: &OptimizerConfig,
        kind: ProjectionKind,
        flavor: ResidualFlavor,
        seed_shift: u32,
    ) -> Self {
        let shared = shared_dct(metas);
        let states = metas
            .iter()
            .enumerate()
            .map(|(i, meta)| {
                if meta.kind.low_rank_eligible() {
                    let (rr, cc) = meta.oriented();
                    let r = cfg.rank.min(cc).min(rr);
                    ProjLayer::LowRank {
                        proj: kind.build(
                            cc,
                            r,
                            shared.get(&cc).cloned(),
                            cfg.seed ^ ((i as u64) << seed_shift),
                        ),
                        m: Matrix::zeros(rr, r),
                        v: Matrix::zeros(rr, r),
                    }
                } else {
                    ProjLayer::Adam(AdamState::new(meta.rows, meta.cols))
                }
            })
            .collect();
        LegacyProjAdamW {
            metas: metas.to_vec(),
            states,
            cfg: cfg.clone(),
            flavor,
            step: 0,
        }
    }
}

impl LegacyOptimizer for LegacyProjAdamW {
    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], lr: f32) {
        self.step += 1;
        let t = self.step;
        let c = &self.cfg;
        let refresh = t == 1 || t % c.update_interval.max(1) as u64 == 0;
        for i in 0..params.len() {
            let meta = &self.metas[i];
            match &mut self.states[i] {
                ProjLayer::Adam(st) => st.update(
                    &mut params[i], &grads[i], lr, c.beta1, c.beta2, c.eps,
                    c.weight_decay, t,
                ),
                ProjLayer::LowRank { proj, m, v } => {
                    let g = orient(meta, &grads[i]);
                    let g_low = if refresh {
                        proj.refresh_and_project(&g)
                    } else {
                        proj.project(&g)
                    };
                    let sc = AdamScalars::new(c.beta1, c.beta2, c.eps, t);
                    let mut u_low = Matrix::zeros(g_low.rows, g_low.cols);
                    adam_moments_into(
                        &mut u_low.data, &g_low.data, &mut m.data, &mut v.data, &sc,
                    );
                    let mut u = proj.back(&u_low);
                    match self.flavor {
                        ResidualFlavor::Discard => {}
                        ResidualFlavor::FiraNorm => {
                            let phi =
                                (u_low.fro_norm() / (g_low.fro_norm() + 1e-12)) as f32;
                            let mut resid = proj.back(&g_low);
                            resid.sub_from(&g);
                            u.axpy(phi, &resid);
                        }
                        ResidualFlavor::Sign => {
                            let mut resid = proj.back(&g_low);
                            resid.sub_from(&g);
                            for (uv, &rv) in u.data.iter_mut().zip(resid.data.iter()) {
                                if rv != 0.0 {
                                    *uv += rv.signum();
                                }
                            }
                        }
                    }
                    params[i].scale(1.0 - lr * c.weight_decay);
                    if meta.needs_transpose() {
                        params[i].axpy_t(-lr, &u);
                    } else {
                        params[i].axpy(-lr, &u);
                    }
                }
            }
        }
    }
}

// ---- legacy LDAdamW ------------------------------------------------------

enum LdLayer {
    LowRank {
        proj: BlockPower,
        prev_basis: Matrix,
        m: Matrix,
        v: Matrix,
        ef: EfBuffer,
        first: bool,
    },
    Adam(AdamState),
}

struct LegacyLdAdamW {
    metas: Vec<LayerMeta>,
    states: Vec<LdLayer>,
    cfg: OptimizerConfig,
    step: u64,
}

impl LegacyLdAdamW {
    fn new(metas: &[LayerMeta], cfg: &OptimizerConfig) -> Self {
        let states = metas
            .iter()
            .map(|meta| {
                if meta.kind.low_rank_eligible() {
                    let (rr, cc) = meta.oriented();
                    let r = cfg.rank.min(cc).min(rr);
                    LdLayer::LowRank {
                        proj: BlockPower::new(cc, r, 2),
                        prev_basis: Matrix::zeros(cc, r),
                        m: Matrix::zeros(rr, r),
                        v: Matrix::zeros(rr, r),
                        ef: EfBuffer::new(EfMode::F32, rr, cc),
                        first: true,
                    }
                } else {
                    LdLayer::Adam(AdamState::new(meta.rows, meta.cols))
                }
            })
            .collect();
        LegacyLdAdamW { metas: metas.to_vec(), states, cfg: cfg.clone(), step: 0 }
    }
}

impl LegacyOptimizer for LegacyLdAdamW {
    fn step(&mut self, params: &mut [Matrix], grads: &[Matrix], lr: f32) {
        self.step += 1;
        let t = self.step;
        let c = &self.cfg;
        for i in 0..params.len() {
            let meta = &self.metas[i];
            match &mut self.states[i] {
                LdLayer::Adam(st) => st.update(
                    &mut params[i], &grads[i], lr, c.beta1, c.beta2, c.eps,
                    c.weight_decay, t,
                ),
                LdLayer::LowRank { proj, prev_basis, m, v, ef, first } => {
                    let mut g = orient(meta, &grads[i]);
                    ef.add_into(&mut g);
                    let g_low = proj.refresh_and_project(&g);
                    if !*first {
                        let rot = proj.rotation_from(prev_basis);
                        *m = matmul(m, &rot);
                        *v = matmul(v, &rot);
                        for x in &mut v.data {
                            *x = x.abs();
                        }
                    }
                    *first = false;
                    *prev_basis = proj.basis();
                    let mut back = proj.back(&g_low);
                    back.sub_from(&g);
                    ef.store(&back);
                    let sc = AdamScalars::new(c.beta1, c.beta2, c.eps, t);
                    let mut u_low = Matrix::zeros(g_low.rows, g_low.cols);
                    adam_moments_into(
                        &mut u_low.data, &g_low.data, &mut m.data, &mut v.data, &sc,
                    );
                    let u = proj.back(&u_low);
                    params[i].scale(1.0 - lr * c.weight_decay);
                    if meta.needs_transpose() {
                        params[i].axpy_t(-lr, &u);
                    } else {
                        params[i].axpy(-lr, &u);
                    }
                }
            }
        }
    }
}

// ---- the equivalence harness --------------------------------------------

fn assert_equivalent(
    kind: &OptimizerKind,
    cfg: &OptimizerConfig,
    reference: &mut dyn LegacyOptimizer,
    steps: usize,
    tag: &str,
) {
    let metas = layer_zoo();
    let grads = grad_seq(&metas, steps, 0x5eed);
    let mut engine = build_optimizer(kind, &metas, cfg);
    let mut p_engine: Vec<Matrix> =
        metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
    let mut p_ref = p_engine.clone();
    for (step, g) in grads.iter().enumerate() {
        // a decaying lr exercises the schedule-dependence of the decay term
        let lr = 1e-2 / (1.0 + step as f32 * 0.1);
        engine.step(&mut p_engine, g, lr);
        reference.step(&mut p_ref, g, lr);
        for (li, (a, b)) in p_engine.iter().zip(&p_ref).enumerate() {
            assert_eq!(a.shape(), b.shape(), "{tag}: layer {li} shape, step {step}");
            assert_eq!(
                bits(a),
                bits(b),
                "{tag}: layer {li} ({}) diverged from the legacy loop at step {step}",
                metas[li].name
            );
        }
        if let Some(want) = reference.errors() {
            let got = engine.projection_errors().expect("instrumented engine");
            assert_eq!(got, want, "{tag}: projection errors, step {step}");
        }
    }
}

#[test]
fn dct_adamw_engine_matches_legacy_loop() {
    // Q8 EF + a GaLore-ish cadence: refresh AND project-only steps, index
    // rotation across refreshes, quantized EF round-trips.
    let cfg = OptimizerConfig {
        rank: 8,
        update_interval: 3,
        ef_mode: EfMode::Q8,
        threads: Some(1),
        ..Default::default()
    };
    let mut r = LegacyDctAdamW::new(&layer_zoo(), &cfg);
    assert_equivalent(&OptimizerKind::DctAdamW, &cfg, &mut r, 12, "dct-adamw/q8");

    // every-step refresh + no EF + rank above the Bluestein width (clamp)
    let cfg = OptimizerConfig {
        rank: 30,
        update_interval: 1,
        ef_mode: EfMode::None,
        threads: Some(1),
        ..Default::default()
    };
    let mut r = LegacyDctAdamW::new(&layer_zoo(), &cfg);
    assert_equivalent(&OptimizerKind::DctAdamW, &cfg, &mut r, 12, "dct-adamw/none");
}

#[test]
fn trion_engine_matches_legacy_loop() {
    let cfg = OptimizerConfig { rank: 8, threads: Some(1), ..Default::default() };
    let mut r = LegacyTrion::new(&layer_zoo(), &cfg);
    assert_equivalent(&OptimizerKind::Trion, &cfg, &mut r, 12, "trion");

    // instrumented: the Figure-1 projection errors must match too
    let cfg = OptimizerConfig {
        rank: 8,
        instrument: true,
        threads: Some(1),
        ..Default::default()
    };
    let mut r = LegacyTrion::new(&layer_zoo(), &cfg);
    assert_equivalent(&OptimizerKind::Trion, &cfg, &mut r, 12, "trion/instrumented");
}

#[test]
fn galore_engine_matches_legacy_loop() {
    // stock GaLore: SVD source (whatever cfg.projection says), cadence 3
    let cfg = OptimizerConfig {
        rank: 8,
        update_interval: 3,
        threads: Some(1),
        ..Default::default()
    };
    let mut r = LegacyProjAdamW::new(
        &layer_zoo(),
        &cfg,
        ProjectionKind::Svd,
        ResidualFlavor::Discard,
        8,
    );
    assert_equivalent(&OptimizerKind::GaLore, &cfg, &mut r, 12, "galore");
}

#[test]
fn fira_engine_matches_legacy_loop() {
    // RandPerm pins fira's legacy per-layer seed derivation
    // (seed ^ (i << 12)) — DCT/SVD never touch the seed.
    for (proj, tag) in [
        (ProjectionKind::Dct { norm: RankNorm::L2, use_makhoul: true }, "fira+dct"),
        (ProjectionKind::Svd, "fira+svd"),
        (ProjectionKind::RandPerm, "fira+randperm"),
    ] {
        let cfg = OptimizerConfig {
            rank: 8,
            update_interval: 3,
            projection: proj.clone(),
            seed: 123,
            threads: Some(1),
            ..Default::default()
        };
        let mut r =
            LegacyProjAdamW::new(&layer_zoo(), &cfg, proj, ResidualFlavor::FiraNorm, 12);
        assert_equivalent(&OptimizerKind::Fira, &cfg, &mut r, 12, tag);
    }
}

#[test]
fn frugal_engine_matches_legacy_loop() {
    // DCT (the default) and RandPerm — the latter pins the per-layer seed
    // derivation (seed ^ (i << 4)) the legacy constructor used.
    for (proj, tag) in [
        (ProjectionKind::Dct { norm: RankNorm::L2, use_makhoul: true }, "frugal+dct"),
        (ProjectionKind::RandPerm, "frugal+randperm"),
    ] {
        let cfg = OptimizerConfig {
            rank: 8,
            update_interval: 3,
            projection: proj.clone(),
            seed: 99,
            threads: Some(1),
            ..Default::default()
        };
        let mut r =
            LegacyProjAdamW::new(&layer_zoo(), &cfg, proj, ResidualFlavor::Sign, 4);
        assert_equivalent(&OptimizerKind::Frugal, &cfg, &mut r, 12, tag);
    }
}

#[test]
fn ldadamw_engine_matches_legacy_loop() {
    let cfg = OptimizerConfig { rank: 8, threads: Some(1), ..Default::default() };
    let mut r = LegacyLdAdamW::new(&layer_zoo(), &cfg);
    assert_equivalent(&OptimizerKind::LdAdamW, &cfg, &mut r, 12, "ldadamw");
}

// ---- novel grid point: config alone → engine → convergence ---------------

#[test]
fn novel_grid_point_from_config_alone_converges() {
    // GaLore cadence + DCT source + Q8 error feedback: not one of the six
    // published methods, no new optimizer file — just config keys.
    let mut cfg = TrainConfig::default();
    for (k, v) in [
        ("optimizer", "galore"),
        ("rank", "4"),
        ("update-interval", "50"),
        ("weight-decay", "0.0"),
        ("source", "dct"),
        ("residual", "ef"),
        ("ef-mode", "q8"),
    ] {
        cfg.apply(k, v).unwrap();
    }
    let metas = vec![LayerMeta::new("w", 10, 8, ParamKind::Linear)];
    let mut opt = cfg.build_optimizer(&metas).unwrap();
    assert_eq!(opt.name(), "engine(dct+adamw+ef-q8,T50)");
    let mut rng = Pcg64::seed(0);
    let target = Matrix::randn(10, 8, 0.5, &mut rng);
    let mut params = vec![Matrix::zeros(10, 8)];
    for _ in 0..500 {
        let g = params[0].sub(&target).scaled(2.0);
        opt.step(&mut params, &[g], 0.05);
    }
    let err = params[0].sub(&target).fro_norm() / target.fro_norm();
    // the Q8 EF recovers the between-refresh residual, so the stale
    // subspace still reaches dct-adamw-like error levels
    assert!(err < 0.3, "rel err={err}");
}
