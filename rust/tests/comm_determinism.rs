//! The subspace-compressed collectives contract (`comm=subspace`,
//! `coordinator::compressed`):
//!
//! * a fixed `(world, comm, wire)` point is **bit-identical** across thread
//!   counts — the sync schemes must not introduce any lane-dependent FP
//!   order on top of the already-pinned collectives and optimizer step;
//! * at `world == 1` the compressed scheme degenerates to the dense
//!   passthrough, `to_bits`-equal trajectories and zero wire bytes;
//! * byte accounting is exact: a compressed step moves the r×R coefficient
//!   volume per low-rank layer (≈ `r/C` of dense) — under `wire=q8` a
//!   further ~4× less (1 byte/elem + a 4-byte scale per transfer) — while
//!   dense-path layers and refresh steps move dense f32 volume, and
//!   refreshes additionally account the basis broadcast + agreement
//!   all-gather;
//! * EF residual state is ZeRO-sharded: per-worker `state_bytes` is
//!   constant in world size;
//! * q8-wire error feedback still converges: the quantization error folds
//!   into the residual, so the compressed trajectory tracks dense on the
//!   quadratic smoke problem;
//! * the scheme composes with the fault-tolerance machinery: worker-lane
//!   retry and checkpoint-v2 save/restore (the `sync` section) both
//!   reproduce the clean trajectory to the bit.
//!
//! Everything drives `Optimizer` + `GradSync` + `Communicator` directly
//! with synthetic per-worker gradients (PJRT stays stubbed), mirroring
//! `tests/resume_determinism.rs` / `tests/fault_recovery.rs`. Tests that
//! don't pin a wire-specific byte count build their sync through
//! `WireFormat::from_env()`, so the `FFT_SUBSPACE_WIRE` matrix axis
//! (`make test-matrix`) sweeps the whole suite across both formats.

use std::sync::Arc;

use fft_subspace::coordinator::{
    build_grad_sync, CommMode, CommModel, Communicator, GradSync, WireFormat,
    WorkerSet,
};
use fft_subspace::optim::{
    build_optimizer, LayerMeta, Optimizer, OptimizerConfig, OptimizerKind, ParamKind,
};
use fft_subspace::parallel::ThreadPool;
use fft_subspace::tensor::Matrix;
use fft_subspace::train::checkpoint::{self, TrainState};
use fft_subspace::train::{FaultInjector, FaultPlan};
use fft_subspace::util::Pcg64;

/// Same mixed layer zoo as the resume/fault suites: tall, wide (transpose
/// orientation), a Bluestein width (24), square, plus dense-path params.
fn layer_zoo() -> Vec<LayerMeta> {
    vec![
        LayerMeta::new("wq", 48, 32, ParamKind::Linear),
        LayerMeta::new("w_gate", 32, 48, ParamKind::Linear),
        LayerMeta::new("wk", 40, 24, ParamKind::Linear),
        LayerMeta::new("wv", 32, 32, ParamKind::Linear),
        LayerMeta::new("norm", 1, 32, ParamKind::Norm),
        LayerMeta::new("embed", 64, 32, ParamKind::Embed),
    ]
}

/// Worker `w`'s gradient set at `step` — a pure function of `(step, w)`,
/// so lane retries replay the exact bytes and every run shape (any thread
/// count, interrupted or not) consumes identical inputs.
fn grad_for(metas: &[LayerMeta], step: usize, w: usize) -> Vec<Matrix> {
    metas
        .iter()
        .enumerate()
        .map(|(pi, m)| {
            let mut rng =
                Pcg64::new(1_000 + step as u64, ((w as u64) << 16) | pi as u64);
            Matrix::randn(m.rows, m.cols, 0.1, &mut rng)
        })
        .collect()
}

fn bits(params: &[Matrix]) -> Vec<Vec<u32>> {
    params
        .iter()
        .map(|p| p.data.iter().map(|v| v.to_bits()).collect())
        .collect()
}

fn decaying_lr(step: usize) -> f32 {
    1e-2 / (1.0 + step as f32 * 0.1)
}

fn zero_params(metas: &[LayerMeta]) -> Vec<Matrix> {
    metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect()
}

/// Rank 8, refresh cadence 3 (refreshes at t = 1, 3, 6, 9 — compressed
/// steps in between), explicit thread count.
fn opt_for(metas: &[LayerMeta], threads: usize) -> Box<dyn Optimizer> {
    let cfg = OptimizerConfig {
        rank: 8,
        update_interval: 3,
        threads: Some(threads),
        ..Default::default()
    };
    build_optimizer(&OptimizerKind::DctAdamW, metas, &cfg)
}

/// Drive `steps` synchronized optimizer steps at one `(mode, world,
/// threads)` point; returns the final param bits and the wire-byte stats
/// `(all_reduce, broadcast, all_gather)`.
fn run_trajectory(
    mode: CommMode,
    world: usize,
    threads: usize,
    steps: usize,
) -> (Vec<Vec<u32>>, (u64, u64, u64)) {
    let metas = layer_zoo();
    let mut opt = opt_for(&metas, threads);
    let mut sync = build_grad_sync(mode, WireFormat::from_env(), world, &metas);
    let pool = Arc::new(ThreadPool::new(threads));
    let mut comm = Communicator::with_pool(world, CommModel::default(), pool);
    let mut params = zero_params(&metas);
    let mut g = Vec::new();
    for step in 0..steps {
        let mut wg: Vec<Vec<Matrix>> =
            (0..world).map(|w| grad_for(&metas, step, w)).collect();
        sync.reduce(&mut wg, opt.as_ref(), &mut comm, &mut g);
        opt.step(&mut params, &g, decaying_lr(step));
        sync.after_step(opt.as_ref(), &mut comm);
    }
    (
        bits(&params),
        (
            comm.stats.all_reduce_bytes,
            comm.stats.broadcast_bytes,
            comm.stats.all_gather_bytes,
        ),
    )
}

/// Bit-identity across thread counts for every (world, comm) grid point —
/// including the byte accounting, which must not depend on lanes either.
#[test]
fn trajectories_bit_identical_across_lane_counts() {
    for world in [1usize, 2, 4] {
        for mode in [CommMode::Dense, CommMode::Subspace] {
            let (p1, b1) = run_trajectory(mode, world, 1, 8);
            let (p3, b3) = run_trajectory(mode, world, 3, 8);
            assert_eq!(p1, p3, "world={world} comm={} params", mode.name());
            assert_eq!(b1, b3, "world={world} comm={} bytes", mode.name());
        }
    }
}

/// At world=1 the compressed scheme is the dense passthrough: `to_bits`-
/// equal trajectory, and neither mode moves a single wire byte.
#[test]
fn world_one_subspace_equals_dense() {
    let (pd, bd) = run_trajectory(CommMode::Dense, 1, 1, 9);
    let (ps, bs) = run_trajectory(CommMode::Subspace, 1, 1, 9);
    assert_eq!(pd, ps);
    assert_eq!(bd, (0, 0, 0));
    assert_eq!(bs, (0, 0, 0));
}

/// Exact byte accounting at world=4: a compressed step moves the r×R
/// coefficient ring volume per low-rank layer plus dense volume for the
/// dense-path params; refresh steps move dense volume everywhere and add
/// the basis broadcast + agreement all-gather.
#[test]
fn compressed_step_bytes_match_rank_ratio() {
    let world = 4usize;
    let metas = layer_zoo();
    let mut opt = opt_for(&metas, 1);
    // byte counts below pin the f32 wire model — explicit, so the
    // FFT_SUBSPACE_WIRE matrix axis can't skew them
    let mut sync = build_grad_sync(CommMode::Subspace, WireFormat::F32, world, &metas);
    let mut comm = Communicator::new(world, CommModel::default());
    let mut params = zero_params(&metas);
    let mut step_one = |step: usize,
                        sync: &mut Box<dyn GradSync>,
                        opt: &mut Box<dyn Optimizer>,
                        comm: &mut Communicator,
                        params: &mut Vec<Matrix>| {
        let mut wg: Vec<Vec<Matrix>> =
            (0..world).map(|w| grad_for(&metas, step, w)).collect();
        let mut g = Vec::new();
        sync.reduce(&mut wg, opt.as_ref(), comm, &mut g);
        opt.step(params, &g, decaying_lr(step));
        sync.after_step(opt.as_ref(), comm);
    };
    // t = 1 (refresh), 2, 3 (refresh): warm-up; measured step is t = 4,
    // squarely compressed under cadence 3
    for step in 0..3 {
        step_one(step, &mut sync, &mut opt, &mut comm, &mut params);
    }
    let before = comm.stats.all_reduce_bytes;
    step_one(3, &mut sync, &mut opt, &mut comm, &mut params);
    let moved = comm.stats.all_reduce_bytes - before;

    // ring all-reduce volume for an n-element tensor (f32)
    let ring = |n: u64| 2 * (world as u64 - 1) * n * 4;
    // low-rank layers move oriented-rows × rank coefficients; the norm and
    // embed params reduce dense
    let want_sub = ring(48 * 8) // wq 48×32
        + ring(48 * 8) // w_gate 32×48, oriented 48×32
        + ring(40 * 8) // wk 40×24
        + ring(32 * 8) // wv 32×32
        + ring(32) // norm (dense path)
        + ring(64 * 32); // embed (dense path)
    // chunk rounding: each ring step over W chunks can round up by at most
    // one f32 per hop
    assert!(
        moved.abs_diff(want_sub) <= want_sub / 8 + 1024,
        "compressed step moved {moved}, want ≈ {want_sub}"
    );
    // the same step under dense sync would have moved the full volume —
    // the low-rank layers compress to r/C of it, so well under half total
    let want_dense = ring(48 * 32) * 2 + ring(40 * 24) + ring(32 * 32) + ring(32)
        + ring(64 * 32);
    assert!(
        moved < want_dense / 2,
        "compressed step moved {moved}, dense would move {want_dense}"
    );
    // refresh boundaries accounted the basis broadcast + agreement gather
    assert!(comm.stats.broadcast_bytes > 0);
    assert!(comm.stats.all_gather_bytes > 0);
}

/// Fault-tolerance composition: an injected worker-lane failure during
/// gradient staging is absorbed by the bounded `WorkerSet` retry, and the
/// `comm=subspace` run still lands on the clean trajectory's bits (the
/// per-worker EF residuals see identical inputs either way).
#[test]
fn worker_fail_recovers_bit_identical_under_subspace() {
    let world = 4usize;
    let steps = 6usize;
    let metas = layer_zoo();
    let run = |plan: Option<&str>| {
        let mut opt = opt_for(&metas, 1);
        let mut sync =
            build_grad_sync(CommMode::Subspace, WireFormat::from_env(), world, &metas);
        let pool = Arc::new(ThreadPool::new(2));
        let ws = WorkerSet::new(world, Arc::clone(&pool));
        let mut comm = Communicator::with_pool(world, CommModel::default(), pool);
        let injector =
            plan.map(|p| FaultInjector::new(FaultPlan::parse(p).unwrap()));
        let mut params = zero_params(&metas);
        let mut g = Vec::new();
        for step in 0..steps {
            // stage per-worker gradients on the worker lanes, the injected
            // failure firing before the (pure) draw — the retry replays it
            let mut wg: Vec<Vec<Matrix>> = ws.run(|w| {
                if let Some(inj) = &injector {
                    inj.maybe_fail_worker(step, w);
                }
                grad_for(&metas, step, w)
            });
            sync.reduce(&mut wg, opt.as_ref(), &mut comm, &mut g);
            opt.step(&mut params, &g, decaying_lr(step));
            sync.after_step(opt.as_ref(), &mut comm);
        }
        bits(&params)
    };
    let clean = run(None);
    let faulted = run(Some("worker-fail@2.1"));
    assert_eq!(clean, faulted);
}

/// Checkpoint composition: interrupting a `comm=subspace` run mid-cycle
/// (live EF residuals), writing a v2 checkpoint with the `sync` section,
/// and restoring into a **fresh** optimizer + sync object reproduces the
/// uninterrupted trajectory to the bit.
#[test]
fn subspace_sync_resumes_bit_identical_through_v2_file() {
    let world = 2usize;
    let (n, k) = (9usize, 5usize); // k=5 sits between refreshes (t=3, t=6)
    let metas = layer_zoo();

    // uninterrupted reference
    let wire = WireFormat::from_env();
    let mut ref_opt = opt_for(&metas, 1);
    let mut ref_sync = build_grad_sync(CommMode::Subspace, wire, world, &metas);
    let mut ref_comm = Communicator::new(world, CommModel::default());
    let mut ref_params = zero_params(&metas);
    let mut g = Vec::new();
    for step in 0..n {
        let mut wg: Vec<Vec<Matrix>> =
            (0..world).map(|w| grad_for(&metas, step, w)).collect();
        ref_sync.reduce(&mut wg, ref_opt.as_ref(), &mut ref_comm, &mut g);
        ref_opt.step(&mut ref_params, &g, decaying_lr(step));
        ref_sync.after_step(ref_opt.as_ref(), &mut ref_comm);
    }

    // interrupted at k, saved through the on-disk v2 format
    let mut opt = opt_for(&metas, 1);
    let mut sync = build_grad_sync(CommMode::Subspace, wire, world, &metas);
    let mut comm = Communicator::new(world, CommModel::default());
    let mut params = zero_params(&metas);
    for step in 0..k {
        let mut wg: Vec<Vec<Matrix>> =
            (0..world).map(|w| grad_for(&metas, step, w)).collect();
        sync.reduce(&mut wg, opt.as_ref(), &mut comm, &mut g);
        opt.step(&mut params, &g, decaying_lr(step));
        sync.after_step(opt.as_ref(), &mut comm);
    }
    let mut sync_blob = Vec::new();
    sync.save_state(&mut sync_blob);
    assert!(!sync_blob.is_empty(), "live residuals must serialize");
    let state = TrainState {
        step: k as u64,
        optimizer: opt.name().to_string(),
        opt_state: opt.save_state().unwrap(),
        sync: sync_blob,
    };
    let path = std::env::temp_dir().join(format!(
        "fft_subspace_comm_resume_{}.bin",
        std::process::id()
    ));
    checkpoint::save_v2(&path, &params, &state).unwrap();

    // restore into FRESH objects and finish the run
    let ck = checkpoint::load_full(&path).unwrap();
    let restored = ck.state.unwrap();
    assert_eq!(restored.step, k as u64);
    let mut params = ck.params;
    let mut opt = opt_for(&metas, 1);
    opt.load_state(&restored.opt_state).unwrap();
    let mut sync = build_grad_sync(CommMode::Subspace, wire, world, &metas);
    sync.load_state(&restored.sync).unwrap();
    let mut comm = Communicator::new(world, CommModel::default());
    for step in k..n {
        let mut wg: Vec<Vec<Matrix>> =
            (0..world).map(|w| grad_for(&metas, step, w)).collect();
        sync.reduce(&mut wg, opt.as_ref(), &mut comm, &mut g);
        opt.step(&mut params, &g, decaying_lr(step));
        sync.after_step(opt.as_ref(), &mut comm);
    }
    assert_eq!(bits(&ref_params), bits(&params));
    let _ = std::fs::remove_file(&path);
}

/// Exact q8 wire accounting at world=4: a compressed step under `wire=q8`
/// moves 1 byte per coefficient element plus a 4-byte scale per ring
/// transfer — ≈ 1/4 of the f32 coefficient volume — while the dense-path
/// params keep moving f32.
#[test]
fn q8_wire_compressed_step_moves_quarter_bytes() {
    let world = 4usize;
    let metas = layer_zoo();
    let mut measured = [0u64; 2];
    for (i, wire) in [WireFormat::F32, WireFormat::Q8].into_iter().enumerate() {
        let mut opt = opt_for(&metas, 1);
        let mut sync = build_grad_sync(CommMode::Subspace, wire, world, &metas);
        let mut comm = Communicator::new(world, CommModel::default());
        let mut params = zero_params(&metas);
        let mut g = Vec::new();
        for step in 0..3 {
            let mut wg: Vec<Vec<Matrix>> =
                (0..world).map(|w| grad_for(&metas, step, w)).collect();
            sync.reduce(&mut wg, opt.as_ref(), &mut comm, &mut g);
            opt.step(&mut params, &g, decaying_lr(step));
            sync.after_step(opt.as_ref(), &mut comm);
        }
        let before = comm.stats.all_reduce_bytes;
        let mut wg: Vec<Vec<Matrix>> =
            (0..world).map(|w| grad_for(&metas, 3, w)).collect();
        sync.reduce(&mut wg, opt.as_ref(), &mut comm, &mut g);
        opt.step(&mut params, &g, decaying_lr(3));
        sync.after_step(opt.as_ref(), &mut comm);
        measured[i] = comm.stats.all_reduce_bytes - before;
    }
    let w = world as u64;
    // ring volumes for an n-element tensor: f32 = 4 bytes/elem; q8 =
    // 1 byte/elem + a 4-byte scale on each of the 2(W−1)·W transfers
    let ring_f32 = |n: u64| 2 * (w - 1) * n * 4;
    let ring_q8 = |n: u64| 2 * (w - 1) * n + 2 * (w - 1) * w * 4;
    let want_q8 = ring_q8(48 * 8) // wq 48×32
        + ring_q8(48 * 8) // w_gate 32×48, oriented 48×32
        + ring_q8(40 * 8) // wk 40×24
        + ring_q8(32 * 8) // wv 32×32
        + ring_f32(32) // norm (dense path, always f32)
        + ring_f32(64 * 32); // embed (dense path, always f32)
    assert!(
        measured[1].abs_diff(want_q8) <= want_q8 / 8 + 1024,
        "q8 step moved {}, want ≈ {want_q8} (f32 moved {})",
        measured[1],
        measured[0]
    );
    // the compressed fraction shrank ~4×; the dense-path remainder is
    // shared, so total q8 traffic sits well under the f32 measurement
    assert!(measured[1] < measured[0], "q8 {} vs f32 {}", measured[1], measured[0]);
}

/// ZeRO-sharded EF: each worker persists only its own residual shard, so
/// the per-worker `state_bytes` is the same at every world size (and the
/// serialized v2 blob — which covers all shards — grows instead).
#[test]
fn ef_state_bytes_constant_across_world_sizes() {
    let metas = layer_zoo();
    let base = build_grad_sync(CommMode::Subspace, WireFormat::F32, 2, &metas)
        .state_bytes();
    assert!(base > 0, "low-rank slots must report EF state");
    // one f32 residual per low-rank slot, oriented shapes
    let want = (48 * 32 + 48 * 32 + 40 * 24 + 32 * 32) as u64 * 4;
    assert_eq!(base, want);
    for world in [4usize, 8] {
        let sync = build_grad_sync(CommMode::Subspace, WireFormat::F32, world, &metas);
        assert_eq!(sync.state_bytes(), base, "world={world}");
    }
}

/// q8-wire error feedback converges: on the quadratic smoke problem
/// (per-worker targets, grad_w = 2(p − t_w)) the q8 compressed trajectory
/// reaches the same neighborhood of the mean target as the dense baseline
/// — the quantization error is fed back, not dropped.
#[test]
fn q8_wire_ef_converges_on_quadratic() {
    let world = 4usize;
    let steps = 500usize;
    let metas = vec![LayerMeta::new("wq", 48, 32, ParamKind::Linear)];
    // fixed per-worker targets; the mean gradient drives p toward t̄
    let targets: Vec<Matrix> = (0..world)
        .map(|w| {
            let mut rng = Pcg64::new(77, w as u64);
            Matrix::randn(48, 32, 1.0, &mut rng)
        })
        .collect();
    let mut t_bar = Matrix::zeros(48, 32);
    for t in &targets {
        t_bar.axpy(1.0 / world as f32, t);
    }
    let run = |mode: CommMode, wire: WireFormat| {
        let mut opt = opt_for(&metas, 1);
        let mut sync = build_grad_sync(mode, wire, world, &metas);
        let mut comm = Communicator::new(world, CommModel::default());
        let mut params = zero_params(&metas);
        let mut g = Vec::new();
        for _ in 0..steps {
            let mut wg: Vec<Vec<Matrix>> = (0..world)
                .map(|w| vec![params[0].sub(&targets[w]).scaled(2.0)])
                .collect();
            sync.reduce(&mut wg, opt.as_ref(), &mut comm, &mut g);
            opt.step(&mut params, &g, 1e-2);
            sync.after_step(opt.as_ref(), &mut comm);
        }
        params[0].sub(&t_bar).fro_norm() / t_bar.fro_norm()
    };
    let dense_err = run(CommMode::Dense, WireFormat::F32) as f64;
    let q8_err = run(CommMode::Subspace, WireFormat::Q8) as f64;
    assert!(dense_err < 0.15, "dense baseline failed to converge: {dense_err}");
    assert!(q8_err < 0.15, "q8-wire EF failed to converge: {q8_err}");
    // within tolerance of the dense baseline, not merely "converged"
    assert!(
        (q8_err - dense_err).abs() < 0.05,
        "q8 {q8_err} drifted from dense {dense_err}"
    );
}
