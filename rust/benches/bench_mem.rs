//! Optimizer-state memory sweep — the paper's "up to 25% less optimizer
//! memory" claim as a tracked artifact.
//!
//! Unlike the timing benches this records **exact byte counts** (from
//! `Optimizer::memory_report`, which sums every persistent store at its
//! true dtype width), so the numbers are deterministic: six engine presets
//! × state dtypes {f32, bf16, q8} × two synthetic transformer models, plus
//! the dense Adam f32/bf16 baselines. Every record carries its ratio to the
//! dense Adam f32 baseline of the same model — the paper-comparable column.
//!
//! Emits `BENCH_MEM.json` (override with `BENCH_MEM_OUT=path`) via
//! `make bench-mem`. The committed file is regenerated, not hand-edited;
//! `optim/engine/tests.rs::bf16_low_rank_state_beats_adam_by_the_paper_margin`
//! pins the headline claim (low-rank + bf16 ≥ 20% below Adam) in the test
//! suite so drift fails CI, not just the artifact.

use fft_subspace::bench::models::transformer_stack;
use fft_subspace::optim::{build_optimizer, LayerMeta, OptimizerConfig, OptimizerKind};
use fft_subspace::tensor::StateDtype;
use fft_subspace::util::json::{num, obj, s, Json};

/// Transformer-ish model (shared `bench::models::transformer_stack` zoo,
/// mirrored by the python regenerator comment in BENCH_MEM.json — keep the
/// shapes in sync with the engine test above).
fn model(name: &str, d: usize, blocks: usize, vocab: usize) -> (String, Vec<LayerMeta>) {
    (name.to_string(), transformer_stack(d, blocks, vocab))
}

fn main() {
    let rank = 32usize;
    let models = vec![
        model("bench-small", 128, 4, 256),
        model("bench-large", 256, 8, 256),
    ];
    println!(
        "== bench_mem (exact optimizer-state bytes, rank {rank}; six presets \
         × dtypes {{f32, bf16, q8}} × two models vs dense Adam f32) ==\n"
    );

    let mut records: Vec<Json> = Vec::new();
    for (model_name, metas) in &models {
        let params: usize = metas.iter().map(|m| m.rows * m.cols).sum();
        // dense Adam f32 — the baseline every ratio is against
        let base_cfg = OptimizerConfig { rank, ..Default::default() };
        let adam_f32 =
            build_optimizer(&OptimizerKind::AdamW, metas, &base_cfg).memory_report().total();
        println!("{model_name}: {params} params, adam(f32) = {adam_f32} bytes");

        let mut push = |opt_name: &str, dtype: StateDtype, total: u64| {
            let ratio = total as f64 / adam_f32 as f64;
            println!(
                "  {:<10} state={:<4} {:>12} bytes  ({:>5.1}% of adam-f32)",
                opt_name,
                dtype.name(),
                total,
                ratio * 100.0
            );
            records.push(obj(vec![
                ("model", s(model_name)),
                ("params", num(params as f64)),
                ("optimizer", s(opt_name)),
                ("state_dtype", s(dtype.name())),
                ("rank", num(rank as f64)),
                ("total_bytes", num(total as f64)),
                ("adam_f32_bytes", num(adam_f32 as f64)),
                ("ratio_vs_adam_f32", num(ratio)),
            ]));
        };

        for dtype in [StateDtype::F32, StateDtype::Bf16, StateDtype::Q8] {
            let cfg = OptimizerConfig { rank, state_dtype: dtype, ..Default::default() };
            push("adamw", dtype, build_optimizer(&OptimizerKind::AdamW, metas, &cfg)
                .memory_report()
                .total());
            for kind in [
                OptimizerKind::DctAdamW,
                OptimizerKind::Trion,
                OptimizerKind::GaLore,
                OptimizerKind::Fira,
                OptimizerKind::Frugal,
                OptimizerKind::LdAdamW,
            ] {
                let cfg = OptimizerConfig {
                    rank,
                    state_dtype: dtype,
                    update_interval: if kind == OptimizerKind::GaLore { 200 } else { 1 },
                    ..Default::default()
                };
                let total = build_optimizer(&kind, metas, &cfg).memory_report().total();
                push(kind.name(), dtype, total);
            }
        }
        println!();
    }

    let out = std::env::var("BENCH_MEM_OUT").unwrap_or_else(|_| "BENCH_MEM.json".into());
    let doc = obj(vec![
        ("version", num(1.0)),
        ("records", Json::Arr(records)),
    ]);
    match std::fs::write(&out, doc.to_string()) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("failed to write {out}: {e}"),
    }
}
