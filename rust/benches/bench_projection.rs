//! Projection-builder microbench: the per-step cost of each low-rank
//! subspace method across shapes and ranks — the mechanism behind Table 1's
//! "Trion runtime is rank-independent, Dion's is not" and the Appendix C
//! Makhoul-vs-matmul speedup.
//!
//! Emits `BENCH_PROJ.json` (override with `BENCH_PROJ_OUT=path`) so future
//! PRs can track the perf trajectory numerically:
//!
//! * group `similarity` — Makhoul real-input FFT vs the pre-split
//!   full-complex FFT vs blocked matmul, per shape (rank-independent).
//! * group `selection`  — O(C) partition column selection, per rank.
//! * group `dct_step`   — similarities + selection end to end (workspace
//!   path, zero allocations at steady state).
//! * group `threads`    — the same similarity / dct_step at 1/2/4/8 pool
//!   lanes (row-parallel Makhoul; bit-identical across lane counts).
//! * groups `power_iter_qr` / `block_power` / `svd` — the rank-dependent
//!   (or rank-independent-but-expensive) baselines.

use fft_subspace::bench::{measure, write_bench_json, BenchRecord};
use fft_subspace::fft::cached_plan;
use fft_subspace::linalg::{block_power_iter, power_iter_qr, qr_thin};
use fft_subspace::parallel::ThreadPool;
use fft_subspace::projection::{
    select_top_columns_into, RankNorm, SharedDct,
};
use fft_subspace::tensor::{Matrix, Workspace};
use fft_subspace::util::Pcg64;

fn main() {
    println!("== bench_projection (rank-(in)dependence of the subspace step) ==\n");
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut rng = Pcg64::seed(0);

    // --- similarity transforms: rank-independent, shape-swept -----------
    for &(rows, cols) in &[(256usize, 256usize), (1024, 512), (1024, 1024)] {
        let g = Matrix::randn(rows, cols, 1.0, &mut rng);
        let shared = SharedDct::new(cols);
        let plan = cached_plan(cols);
        let mut ws = Workspace::new();
        let mut s_buf = ws.take(rows, cols);
        // every variant writes into a preallocated buffer so the ratios
        // compare transforms, not allocation behavior
        let mut full_buf = ws.take(rows, cols);
        let mut mm_buf = ws.take(rows, cols);

        let iters = if rows * cols >= 1 << 20 { 5 } else { 10 };
        let mk = measure(&format!("makhoul_real {rows}x{cols}"), 2, iters, || {
            plan.run_into(&g, &mut s_buf);
        });
        let mk_full = measure(&format!("makhoul_fullfft {rows}x{cols}"), 2, iters, || {
            plan.run_full_complex_into(&g, &mut full_buf);
        });
        let mm = measure(&format!("matmul_sim {rows}x{cols}"), 1, iters, || {
            shared.similarities_into(&g, false, &mut mm_buf);
        });
        println!("{}", mk.report());
        println!("{}", mk_full.report());
        println!("{}", mm.report());
        println!(
            "  real-input speedup vs full-complex FFT: {:.2}x, vs matmul: {:.2}x\n",
            mk_full.median_secs / mk.median_secs,
            mm.median_secs / mk.median_secs
        );
        records.push(BenchRecord::new("similarity", "makhoul", rows, cols, 0, mk.clone()));
        records.push(BenchRecord::new("similarity", "makhoul_fullfft", rows, cols, 0, mk_full));
        records.push(BenchRecord::new("similarity", "matmul", rows, cols, 0, mm));

        // --- selection + full DCT step, per rank ------------------------
        for &rank in &[16usize, 32, 64, 128] {
            let mut idx = Vec::new();
            let sel = measure(&format!("select_top r={rank} C={cols}"), 2, 20, || {
                select_top_columns_into(&s_buf, rank, RankNorm::L2, &mut ws, &mut idx);
            });
            records.push(BenchRecord::new("selection", "partition", rows, cols, rank, sel.clone()));

            let step = measure(&format!("dct_step r={rank} {rows}x{cols}"), 1, iters, || {
                plan.run_into(&g, &mut s_buf);
                select_top_columns_into(&s_buf, rank, RankNorm::L2, &mut ws, &mut idx);
            });
            println!("{}", sel.report());
            println!("{}", step.report());
            records.push(BenchRecord::new("dct_step", "makhoul+select", rows, cols, rank, step));
        }
        println!();
    }

    // --- threads sweep: row-parallel similarity + full dct_step ---------
    // Same transform at 1/2/4/8 lanes; 1 lane is the inline sequential
    // path, so the t=1 row doubles as the parallel-overhead baseline.
    {
        let (rows, cols) = (1024usize, 1024usize);
        let g = Matrix::randn(rows, cols, 1.0, &mut rng);
        let plan = cached_plan(cols);
        let mut ws = Workspace::new();
        let mut s_buf = ws.take(rows, cols);
        let mut idx = Vec::new();
        for &t in &[1usize, 2, 4, 8] {
            let pool = ThreadPool::new(t);
            let sim = measure(&format!("makhoul_par t={t} {rows}x{cols}"), 2, 10, || {
                plan.run_into_on(&pool, &g, &mut s_buf);
            });
            println!("{}", sim.report());
            records.push(BenchRecord::new(
                "threads", &format!("similarity_t{t}"), rows, cols, 0, sim,
            ));
            let step = measure(&format!("dct_step_par t={t} r=64"), 1, 10, || {
                plan.run_into_on(&pool, &g, &mut s_buf);
                select_top_columns_into(&s_buf, 64, RankNorm::L2, &mut ws, &mut idx);
            });
            println!("{}", step.report());
            records.push(BenchRecord::new(
                "threads", &format!("dct_step_t{t}"), rows, cols, 64, step,
            ));
        }
        println!();
    }

    // --- simd sweep: the composite subspace step with the vector backend
    // on/off. The scalar leg is the exact `FFT_SUBSPACE_SIMD=0` code path
    // (forced via the runtime override); results are bit-identical by
    // contract, so the ratio is pure kernel speedup. Per-kernel
    // scalar-vs-vector races (matmul family, Makhoul, Adam, column norms)
    // live in `bench_simd` / BENCH_SIMD.json — only the end-to-end
    // dct_step composite is measured here to avoid double bookkeeping.
    {
        let (rows, cols) = (1024usize, 1024usize);
        let g = Matrix::randn(rows, cols, 1.0, &mut rng);
        let plan = cached_plan(cols);
        let mut ws = Workspace::new();
        let mut s_buf = ws.take(rows, cols);
        let mut idx = Vec::new();
        fft_subspace::bench::with_simd_backends(|be| {
            let step = measure(&format!("simd[{be}] dct_step r=64"), 1, 10, || {
                plan.run_into(&g, &mut s_buf);
                select_top_columns_into(&s_buf, 64, RankNorm::L2, &mut ws, &mut idx);
            });
            println!("{}", step.report());
            records.push(BenchRecord::new(
                "simd", &format!("dct_step_{be}"), rows, cols, 64, step,
            ));
        });
        println!();
    }

    // --- rank-dependent baselines at the Table-1 shape ------------------
    let (rows, cols) = (1024usize, 256usize);
    let g = Matrix::randn(rows, cols, 1.0, &mut rng);
    for &rank in &[16usize, 32, 64, 128] {
        // Dion's power-iteration + QR: cost grows with rank.
        let q0 = {
            let z = Matrix::randn(cols, rank, 1.0, &mut rng);
            qr_thin(&z).0
        };
        let dion = measure(&format!("power_iter_qr r={rank}"), 1, 10, || {
            power_iter_qr(&g, &q0)
        });
        // LDAdam's block power iteration (2 inner iters).
        let bpi = measure(&format!("block_power r={rank}"), 1, 5, || {
            block_power_iter(&g, rank, 2, None)
        });
        // GaLore's full SVD (rank-independent but far more expensive).
        let svd = measure(&format!("jacobi_svd r={rank}"), 1, 2, || {
            fft_subspace::linalg::svd_thin(&g)
        });
        println!("{}", dion.report());
        println!("{}", bpi.report());
        println!("{}", svd.report());
        println!();
        records.push(BenchRecord::new("power_iter_qr", "dion", rows, cols, rank, dion));
        records.push(BenchRecord::new("block_power", "ldadam", rows, cols, rank, bpi));
        records.push(BenchRecord::new("svd", "galore", rows, cols, rank, svd));
    }

    let out = std::env::var("BENCH_PROJ_OUT").unwrap_or_else(|_| "BENCH_PROJ.json".into());
    match write_bench_json(&out, &records) {
        Ok(()) => println!("wrote {} records to {out}", records.len()),
        Err(e) => eprintln!("failed to write {out}: {e}"),
    }
}
