//! Projection-builder microbench: the per-step cost of each low-rank
//! projection family at fixed layer shape across ranks — the mechanism
//! behind Table 1's "Trion runtime is rank-independent, Dion's is not".

use fft_subspace::bench::measure;
use fft_subspace::linalg::{block_power_iter, power_iter_qr, qr_thin};
use fft_subspace::projection::{select_top_columns, RankNorm, SharedDct};
use fft_subspace::tensor::Matrix;
use fft_subspace::util::Pcg64;

fn main() {
    println!("== bench_projection (rank-(in)dependence of the subspace step) ==\n");
    let (rows, cols) = (1024, 256);
    let mut rng = Pcg64::seed(0);
    let g = Matrix::randn(rows, cols, 1.0, &mut rng);
    let shared = SharedDct::new(cols);

    for rank in [16usize, 32, 64, 128] {
        // DCT dynamic column selection (Makhoul similarities + norm ranking):
        // the cost does NOT depend on rank.
        let dct = measure(&format!("dct_select r={rank}"), 1, 10, || {
            let s = shared.similarities(&g, true);
            select_top_columns(&s, rank, RankNorm::L2)
        });
        // Dion's power-iteration + QR: cost grows with rank.
        let q0 = {
            let z = Matrix::randn(cols, rank, 1.0, &mut rng);
            qr_thin(&z).0
        };
        let dion = measure(&format!("power_iter_qr r={rank}"), 1, 10, || {
            power_iter_qr(&g, &q0)
        });
        // LDAdam's block power iteration (2 inner iters).
        let bpi = measure(&format!("block_power r={rank}"), 1, 5, || {
            block_power_iter(&g, rank, 2, None)
        });
        // GaLore's full SVD (rank-independent but far more expensive).
        let svd = measure(&format!("jacobi_svd r={rank}"), 1, 2, || {
            fft_subspace::linalg::svd_thin(&g)
        });
        println!("{}", dct.report());
        println!("{}", dion.report());
        println!("{}", bpi.report());
        println!("{}", svd.report());
        println!();
    }
}
