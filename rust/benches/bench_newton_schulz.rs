//! Newton–Schulz cost: full-size (Muon) vs low-rank (Trion) — the paper's
//! "first to reduce Newton–Schulz complexity via a low-rank momentum"
//! claim. Also sweeps NS steps for the accuracy/cost tradeoff.

use fft_subspace::bench::measure;
use fft_subspace::linalg::{newton_schulz, svd_thin};
use fft_subspace::tensor::Matrix;
use fft_subspace::util::Pcg64;

fn main() {
    println!("== bench_newton_schulz (full vs low-rank momentum) ==\n");
    let mut rng = Pcg64::seed(0);
    let (rows, cols) = (1024, 512);
    let full = Matrix::randn(rows, cols, 1.0, &mut rng);

    let full_stats = measure("NS(full 1024x512)  — Muon", 1, 5, || {
        newton_schulz(&full, 5)
    });
    println!("{}", full_stats.report());
    for rank in [32usize, 64, 128, 256] {
        let low = Matrix::randn(rows, rank, 1.0, &mut rng);
        let s = measure(&format!("NS(low  1024x{rank:<4}) — Trion"), 1, 5, || {
            newton_schulz(&low, 5)
        });
        println!(
            "{}  speedup vs full: {:.1}x",
            s.report(),
            full_stats.median_secs / s.median_secs
        );
    }

    println!("\nNS steps vs orthogonality (singular-value spread):");
    let x = Matrix::randn(256, 64, 1.0, &mut rng);
    for steps in [1usize, 3, 5, 8] {
        let o = newton_schulz(&x, steps);
        let sv = svd_thin(&o).s;
        let (lo, hi) = sv.iter().fold((f32::MAX, 0f32), |(l, h), &v| {
            (l.min(v), h.max(v))
        });
        println!("  steps={steps}: singular values in [{lo:.3}, {hi:.3}]");
    }
}
