//! Collectives bench: ring all-reduce and ZeRO broadcast volume/time across
//! world sizes — the communication side of §2.3 (Trion broadcasts low-rank
//! `o_t` + indices instead of the full update).

use fft_subspace::bench::measure;
use fft_subspace::bench::models::square_stack;
use fft_subspace::coordinator::{CommModel, Communicator, ZeroSchedule};
use fft_subspace::optim::{build_optimizer, LayerMeta, OptimizerConfig, OptimizerKind};
use fft_subspace::tensor::Matrix;
use fft_subspace::util::{human, Pcg64};

fn main() {
    println!("== bench_collectives ==\n");
    let n = 256 * 1024; // 1 MiB gradient
    for world in [2usize, 4, 8] {
        let mut rng = Pcg64::seed(0);
        let make = |rng: &mut Pcg64| -> Vec<Matrix> {
            (0..world).map(|_| Matrix::randn(1, n, 1.0, rng)).collect()
        };
        let mut bufs = make(&mut rng);
        let mut comm = Communicator::new(world, CommModel::default());
        let stats = measure(&format!("ring_allreduce 1MiB W={world}"), 1, 8, || {
            comm.all_reduce_mean(&mut bufs);
        });
        println!(
            "{}  (modeled NVLink: {:.1} µs/call)",
            stats.report(),
            comm.stats.modeled_secs / comm.stats.calls.max(1) as f64 * 1e6
        );
    }
    println!();

    // ZeRO broadcast volume per optimizer step (micro-like model, rank 32)
    let metas: Vec<LayerMeta> = square_stack(24, 128);
    let cfg = OptimizerConfig { rank: 32, ..Default::default() };
    println!("ZeRO post-update broadcast volume (24 layers 128x128, W=8, r=32):");
    for kind in [OptimizerKind::AdamW, OptimizerKind::Dion, OptimizerKind::Trion] {
        let opt = build_optimizer(&kind, &metas, &cfg);
        let sched = ZeroSchedule::round_robin(metas.len(), 8);
        let mut comm = Communicator::new(8, CommModel::default());
        let z = sched.account_step(&metas, opt.as_ref(), &mut comm);
        println!(
            "  {:<8} update={:<12} full-equivalent={:<12} saving={:.1}x",
            kind.name(),
            human::bytes(z.update_broadcast_bytes),
            human::bytes(z.full_broadcast_bytes),
            z.full_broadcast_bytes as f64 / z.update_broadcast_bytes.max(1) as f64
        );
    }
}
