//! Collectives bench: ring all-reduce and ZeRO broadcast volume/time across
//! world sizes — the communication side of §2.3 (Trion broadcasts low-rank
//! `o_t` + indices instead of the full update) — plus the dense-vs-subspace
//! gradient-sync comparison (`comm=` subsystem, PR 9): wire bytes, modeled
//! α–β time and wall time per world size, emitted machine-readable to
//! `BENCH_COLLECTIVES.json` (`BENCH_COLLECTIVES_OUT` overrides the path).
//!
//! JSON encoding: `grad_sync_wall` records are ordinary wall-time stats;
//! `grad_sync_modeled` records carry the α–β modeled step time in the same
//! seconds fields; `grad_sync_bytes` records reuse the nanosecond field as
//! a plain byte count (`median_ns` == bytes moved per step) — the harness
//! has no non-time channel, and a self-describing group name beats a
//! second format.

use fft_subspace::bench::models::square_stack;
use fft_subspace::bench::{measure, write_bench_json, BenchRecord, BenchStats};
use fft_subspace::coordinator::{
    build_grad_sync, CommMode, CommModel, Communicator, ZeroSchedule,
};
use fft_subspace::optim::{build_optimizer, LayerMeta, OptimizerConfig, OptimizerKind};
use fft_subspace::tensor::Matrix;
use fft_subspace::util::{human, Pcg64};

/// A `BenchRecord` whose stats carry one already-known scalar instead of
/// measured wall times (see the module docs for the encoding).
fn scalar_record(group: &str, name: &str, dim: usize, rank: usize, secs: f64) -> BenchRecord {
    let stats = BenchStats {
        name: format!("{group} {name}"),
        iters: 1,
        median_secs: secs,
        p10_secs: secs,
        p90_secs: secs,
        mean_secs: secs,
    };
    BenchRecord::new(group, name, dim, dim, rank, stats)
}

fn main() {
    println!("== bench_collectives ==\n");
    let n = 256 * 1024; // 1 MiB gradient
    for world in [2usize, 4, 8] {
        let mut rng = Pcg64::seed(0);
        let make = |rng: &mut Pcg64| -> Vec<Matrix> {
            (0..world).map(|_| Matrix::randn(1, n, 1.0, rng)).collect()
        };
        let mut bufs = make(&mut rng);
        let mut comm = Communicator::new(world, CommModel::default());
        let stats = measure(&format!("ring_allreduce 1MiB W={world}"), 1, 8, || {
            comm.all_reduce_mean(&mut bufs);
        });
        println!(
            "{}  (modeled NVLink: {:.1} µs/call)",
            stats.report(),
            comm.stats.modeled_secs / comm.stats.calls.max(1) as f64 * 1e6
        );
    }
    println!();

    // ZeRO broadcast volume per optimizer step (micro-like model, rank 32)
    let metas: Vec<LayerMeta> = square_stack(24, 128);
    let cfg = OptimizerConfig { rank: 32, ..Default::default() };
    println!("ZeRO post-update broadcast volume (24 layers 128x128, W=8, r=32):");
    for kind in [OptimizerKind::AdamW, OptimizerKind::Dion, OptimizerKind::Trion] {
        let opt = build_optimizer(&kind, &metas, &cfg);
        let sched = ZeroSchedule::round_robin(metas.len(), 8);
        let mut comm = Communicator::new(8, CommModel::default());
        let z = sched.account_step(&metas, opt.as_ref(), &mut comm);
        println!(
            "  {:<8} update={:<12} full-equivalent={:<12} saving={:.1}x",
            kind.name(),
            human::bytes(z.update_broadcast_bytes),
            human::bytes(z.full_broadcast_bytes),
            z.full_broadcast_bytes as f64 / z.update_broadcast_bytes.max(1) as f64
        );
    }
    println!();

    // --- dense vs subspace gradient sync (comm= subsystem, PR 9) --------
    // A steady-state (non-refresh) sync step over a 12×256×256 stack at
    // rank 32: subspace moves r/C = 1/8 of the dense volume per layer.
    let dim = 256usize;
    let metas: Vec<LayerMeta> = square_stack(12, dim);
    let cfg = OptimizerConfig {
        rank: 32,
        update_interval: 3,
        threads: Some(1),
        ..Default::default()
    };
    let mut records: Vec<BenchRecord> = Vec::new();
    println!("gradient sync per step (12 layers 256x256, r=32, steady state):");
    for world in [2usize, 4, 8] {
        for mode in [CommMode::Dense, CommMode::Subspace] {
            let mut opt = build_optimizer(&OptimizerKind::DctAdamW, &metas, &cfg);
            let mut sync = build_grad_sync(mode, world, &metas);
            let mut comm = Communicator::new(world, CommModel::default());
            let mut params: Vec<Matrix> =
                metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
            let mut rng = Pcg64::seed(11);
            let base: Vec<Vec<Matrix>> = (0..world)
                .map(|_| {
                    metas
                        .iter()
                        .map(|m| Matrix::randn(m.rows, m.cols, 0.1, &mut rng))
                        .collect()
                })
                .collect();
            // warm past the early refreshes (cadence 3: t = 1, 3) so the
            // measured reduce is a steady compressed step (t+1 = 5)
            for step in 0..4 {
                let mut wg = base.clone();
                let g = sync.reduce(&mut wg, opt.as_ref(), &mut comm);
                opt.step(&mut params, &g, 1e-3 / (step + 1) as f32);
                sync.after_step(opt.as_ref(), &mut comm);
            }
            // one instrumented step for the byte / modeled-time deltas
            let b0 = comm.stats.all_reduce_bytes;
            let m0 = comm.stats.modeled_secs;
            {
                let mut wg = base.clone();
                let _ = sync.reduce(&mut wg, opt.as_ref(), &mut comm);
            }
            let step_bytes = comm.stats.all_reduce_bytes - b0;
            let step_modeled = comm.stats.modeled_secs - m0;
            // wall time of the reduce itself (clone cost included in both
            // variants identically; the optimizer is NOT stepped, so every
            // iteration replays the same steady compressed step)
            let st = measure(
                &format!("grad_sync {} W={world}", mode.name()),
                1,
                5,
                || {
                    let mut wg = base.clone();
                    sync.reduce(&mut wg, opt.as_ref(), &mut comm)
                },
            );
            println!(
                "  {:<9} W={world}  bytes/step={:<12} modeled={:>9.1} µs  {}",
                mode.name(),
                human::bytes(step_bytes),
                step_modeled * 1e6,
                st.report()
            );
            let tag = format!("{}_w{world}", mode.name());
            records.push(BenchRecord::new("grad_sync_wall", &tag, dim, dim, 32, st));
            records.push(scalar_record("grad_sync_modeled", &tag, dim, 32, step_modeled));
            records.push(scalar_record(
                "grad_sync_bytes",
                &tag,
                dim,
                32,
                step_bytes as f64 * 1e-9, // median_ns == bytes
            ));
        }
    }

    let out = std::env::var("BENCH_COLLECTIVES_OUT")
        .unwrap_or_else(|_| "BENCH_COLLECTIVES.json".into());
    match write_bench_json(&out, &records) {
        Ok(()) => println!("\nwrote {} records to {out}", records.len()),
        Err(e) => eprintln!("failed to write {out}: {e}"),
    }
}
