//! Collectives bench: ring all-reduce and ZeRO broadcast volume/time across
//! world sizes — the communication side of §2.3 (Trion broadcasts low-rank
//! `o_t` + indices instead of the full update) — plus the dense-vs-subspace
//! gradient-sync comparison (`comm=` subsystem, PR 9/10): wire bytes,
//! modeled α–β time and wall time per world size and wire format
//! (`subspace_f32_*` vs `subspace_q8_*` tags), emitted machine-readable to
//! `BENCH_COLLECTIVES.json` (`BENCH_COLLECTIVES_OUT` overrides the path).
//! `FFT_SUBSPACE_WIRE` is deliberately NOT consulted — the sweep is
//! explicit so one run covers every wire.
//!
//! JSON encoding: `grad_sync_wall` records are ordinary wall-time stats;
//! `grad_sync_modeled` records carry the α–β modeled step time in the same
//! seconds fields, amortized over a full `T_u` refresh cycle so the
//! refresh-boundary basis broadcast and agreement all-gather are counted;
//! `grad_sync_bytes` records reuse the nanosecond field as a plain byte
//! count (`median_ns` == bytes moved per steady step) — the harness has no
//! non-time channel, and a self-describing group name beats a second
//! format. `grad_sync_refresh_wall` times the refresh-boundary reduce
//! itself, sequential (`seq_*`) vs pipelined behind staging (`overlap_*`).

use std::sync::Arc;

use fft_subspace::bench::models::square_stack;
use fft_subspace::bench::{measure, write_bench_json, BenchRecord, BenchStats};
use fft_subspace::coordinator::{
    build_grad_sync, CommMode, CommModel, Communicator, WireFormat, ZeroSchedule,
};
use fft_subspace::optim::{build_optimizer, LayerMeta, OptimizerConfig, OptimizerKind};
use fft_subspace::parallel::ThreadPool;
use fft_subspace::tensor::Matrix;
use fft_subspace::util::{human, Pcg64};

/// A `BenchRecord` whose stats carry one already-known scalar instead of
/// measured wall times (see the module docs for the encoding).
fn scalar_record(group: &str, name: &str, dim: usize, rank: usize, secs: f64) -> BenchRecord {
    let stats = BenchStats {
        name: format!("{group} {name}"),
        iters: 1,
        median_secs: secs,
        p10_secs: secs,
        p90_secs: secs,
        mean_secs: secs,
    };
    BenchRecord::new(group, name, dim, dim, rank, stats)
}

fn main() {
    println!("== bench_collectives ==\n");
    let n = 256 * 1024; // 1 MiB gradient
    for world in [2usize, 4, 8] {
        let mut rng = Pcg64::seed(0);
        let make = |rng: &mut Pcg64| -> Vec<Matrix> {
            (0..world).map(|_| Matrix::randn(1, n, 1.0, rng)).collect()
        };
        let mut bufs = make(&mut rng);
        let mut comm = Communicator::new(world, CommModel::default());
        let stats = measure(&format!("ring_allreduce 1MiB W={world}"), 1, 8, || {
            comm.all_reduce_mean(&mut bufs);
        });
        println!(
            "{}  (modeled NVLink: {:.1} µs/call)",
            stats.report(),
            comm.stats.modeled_secs / comm.stats.calls.max(1) as f64 * 1e6
        );
    }
    println!();

    // ZeRO broadcast volume per optimizer step (micro-like model, rank 32)
    let metas: Vec<LayerMeta> = square_stack(24, 128);
    let cfg = OptimizerConfig { rank: 32, ..Default::default() };
    println!("ZeRO post-update broadcast volume (24 layers 128x128, W=8, r=32):");
    for kind in [OptimizerKind::AdamW, OptimizerKind::Dion, OptimizerKind::Trion] {
        let opt = build_optimizer(&kind, &metas, &cfg);
        let sched = ZeroSchedule::round_robin(metas.len(), 8);
        let mut comm = Communicator::new(8, CommModel::default());
        let z = sched.account_step(&metas, opt.as_ref(), &mut comm);
        println!(
            "  {:<8} update={:<12} full-equivalent={:<12} saving={:.1}x",
            kind.name(),
            human::bytes(z.update_broadcast_bytes),
            human::bytes(z.full_broadcast_bytes),
            z.full_broadcast_bytes as f64 / z.update_broadcast_bytes.max(1) as f64
        );
    }
    println!();

    // --- dense vs subspace gradient sync (comm= subsystem, PR 9/10) -----
    // A steady-state (non-refresh) sync step over a 12×256×256 stack at
    // rank 32: subspace moves r/C = 1/8 of the dense volume per layer, and
    // `wire=q8` a further ~4× less on the compressed blocks.
    let dim = 256usize;
    let t_u = 3usize; // refresh cadence — the modeled-time amortization window
    let metas: Vec<LayerMeta> = square_stack(12, dim);
    let cfg = OptimizerConfig {
        rank: 32,
        update_interval: t_u,
        threads: Some(1),
        ..Default::default()
    };
    let mut records: Vec<BenchRecord> = Vec::new();
    println!("gradient sync per step (12 layers 256x256, r=32, steady state):");
    for world in [2usize, 4, 8] {
        for (mode, wire) in [
            (CommMode::Dense, WireFormat::F32),
            (CommMode::Subspace, WireFormat::F32),
            (CommMode::Subspace, WireFormat::Q8),
        ] {
            let mut opt = build_optimizer(&OptimizerKind::DctAdamW, &metas, &cfg);
            let mut sync = build_grad_sync(mode, wire, world, &metas);
            let mut comm = Communicator::new(world, CommModel::default());
            let mut params: Vec<Matrix> =
                metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
            let mut rng = Pcg64::seed(11);
            let base: Vec<Vec<Matrix>> = (0..world)
                .map(|_| {
                    metas
                        .iter()
                        .map(|m| Matrix::randn(m.rows, m.cols, 0.1, &mut rng))
                        .collect()
                })
                .collect();
            let mut g: Vec<Matrix> = Vec::new();
            // warm past the early refreshes (cadence 3: t = 1, 3) so the
            // measured reduce is a steady compressed step (t+1 = 5)
            for step in 0..4 {
                let mut wg = base.clone();
                sync.reduce(&mut wg, opt.as_ref(), &mut comm, &mut g);
                opt.step(&mut params, &g, 1e-3 / (step + 1) as f32);
                sync.after_step(opt.as_ref(), &mut comm);
            }
            // one instrumented reduce for the steady-step byte delta
            let b0 = comm.stats.all_reduce_bytes;
            {
                let mut wg = base.clone();
                sync.reduce(&mut wg, opt.as_ref(), &mut comm, &mut g);
            }
            let step_bytes = comm.stats.all_reduce_bytes - b0;
            // modeled α–β time amortized over one full T_u cycle (steps
            // t = 5, 6, 7 — the t = 6 refresh boundary inside): the dense
            // refresh reduce, the basis broadcast and the agreement
            // all-gather are all in the window. The PR-9 bench timed one
            // steady step and amortized none of them, undercounting
            // subspace traffic.
            let m0 = comm.stats.modeled_secs;
            for step in 4..4 + t_u {
                let mut wg = base.clone();
                sync.reduce(&mut wg, opt.as_ref(), &mut comm, &mut g);
                opt.step(&mut params, &g, 1e-3 / (step + 1) as f32);
                sync.after_step(opt.as_ref(), &mut comm);
            }
            let step_modeled = (comm.stats.modeled_secs - m0) / t_u as f64;
            // wall time of the reduce itself (clone cost included in all
            // variants identically; the optimizer is NOT stepped, so every
            // iteration replays the same steady compressed step)
            let label = if mode == CommMode::Dense {
                "dense".to_string()
            } else {
                format!("{}:{}", mode.name(), wire.name())
            };
            let st = measure(&format!("grad_sync {label} W={world}"), 1, 5, || {
                let mut wg = base.clone();
                sync.reduce(&mut wg, opt.as_ref(), &mut comm, &mut g);
            });
            println!(
                "  {:<13} W={world}  bytes/step={:<12} modeled={:>9.1} µs  {}",
                label,
                human::bytes(step_bytes),
                step_modeled * 1e6,
                st.report()
            );
            let tag = if mode == CommMode::Dense {
                format!("dense_w{world}")
            } else {
                format!("subspace_{}_w{world}", wire.name())
            };
            records.push(BenchRecord::new("grad_sync_wall", &tag, dim, dim, 32, st));
            records.push(scalar_record("grad_sync_modeled", &tag, dim, 32, step_modeled));
            records.push(scalar_record(
                "grad_sync_bytes",
                &tag,
                dim,
                32,
                step_bytes as f64 * 1e-9, // median_ns == bytes
            ));
        }
    }

    // --- refresh-boundary reduce: sequential vs overlapped (PR 10) ------
    // The refresh step's dense all-reduce used to serialize into the p99
    // spike; with a pool-equipped communicator the per-layer ring transfer
    // runs behind the next layer's staging, bit-identically.
    println!("\nrefresh-boundary reduce (dense ring overlapped with staging):");
    for world in [2usize, 4, 8] {
        for pooled in [false, true] {
            let mut opt = build_optimizer(&OptimizerKind::DctAdamW, &metas, &cfg);
            let mut sync =
                build_grad_sync(CommMode::Subspace, WireFormat::F32, world, &metas);
            let mut comm = if pooled {
                let pool = Arc::new(ThreadPool::new(2));
                Communicator::with_pool(world, CommModel::default(), pool)
            } else {
                Communicator::new(world, CommModel::default())
            };
            let mut params: Vec<Matrix> =
                metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
            let mut rng = Pcg64::seed(11);
            let base: Vec<Vec<Matrix>> = (0..world)
                .map(|_| {
                    metas
                        .iter()
                        .map(|m| Matrix::randn(m.rows, m.cols, 0.1, &mut rng))
                        .collect()
                })
                .collect();
            let mut g: Vec<Matrix> = Vec::new();
            // warm to t = 2: the next reduce sits on the t = 3 refresh
            // boundary, and repeating it without stepping the optimizer
            // replays the refresh-path reduce every iteration
            for step in 0..2 {
                let mut wg = base.clone();
                sync.reduce(&mut wg, opt.as_ref(), &mut comm, &mut g);
                opt.step(&mut params, &g, 1e-3 / (step + 1) as f32);
                sync.after_step(opt.as_ref(), &mut comm);
            }
            let name = if pooled { "overlap" } else { "seq" };
            let st = measure(
                &format!("grad_sync_refresh {name} W={world}"),
                1,
                5,
                || {
                    let mut wg = base.clone();
                    sync.reduce(&mut wg, opt.as_ref(), &mut comm, &mut g);
                },
            );
            println!("  {:<8} W={world}  {}", name, st.report());
            records.push(BenchRecord::new(
                "grad_sync_refresh_wall",
                &format!("{name}_w{world}"),
                dim,
                dim,
                32,
                st,
            ));
        }
    }

    let out = std::env::var("BENCH_COLLECTIVES_OUT")
        .unwrap_or_else(|_| "BENCH_COLLECTIVES.json".into());
    match write_bench_json(&out, &records) {
        Ok(()) => println!("\nwrote {} records to {out}", records.len()),
        Err(e) => eprintln!("failed to write {out}: {e}"),
    }
}
