//! Parallel-engine microbench: thread-count sweeps for the row-blocked
//! matmuls, the parallel optimizer step (`step_layers_parallel`), and the
//! threaded ring all-reduce — the wall-clock side of the determinism
//! contract (the bits are pinned by `tests/parallel_determinism.rs`; this
//! binary records how much time the threads buy).
//!
//! Emits `BENCH_PAR.json` (override with `BENCH_PAR_OUT=path`):
//!
//! * group `matmul_par`   — 1024×512·512×512 `matmul_into_on`, per lanes.
//! * group `optim_step`   — full DctAdamW step over a 24-layer zoo, per
//!   lanes (the tentpole number: layers step concurrently).
//! * group `all_reduce`   — 8-worker ring all-reduce of 1M floats, per
//!   lanes.
//!
//! Run via `make bench-par` in a toolchain-equipped environment.

use fft_subspace::bench::{measure, write_bench_json, BenchRecord};
use fft_subspace::coordinator::{CommModel, Communicator};
use fft_subspace::optim::{LayerMeta, Optimizer, OptimizerSpec, ParamKind};
use fft_subspace::parallel::ThreadPool;
use fft_subspace::tensor::{matmul_into_on, Matrix};
use fft_subspace::util::Pcg64;
use std::sync::Arc;

const LANES: [usize; 4] = [1, 2, 4, 8];

fn main() {
    println!("== bench_parallel (thread-count sweeps; results bit-identical per lane count) ==\n");
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut rng = Pcg64::seed(0);

    // --- row-blocked matmul ---------------------------------------------
    let (m, k, n) = (1024usize, 512usize, 512usize);
    let a = Matrix::randn(m, k, 1.0, &mut rng);
    let b = Matrix::randn(k, n, 1.0, &mut rng);
    let mut c = Matrix::zeros(m, n);
    for &t in &LANES {
        let pool = ThreadPool::new(t);
        let st = measure(&format!("matmul_par t={t} {m}x{k}x{n}"), 2, 10, || {
            matmul_into_on(&pool, &a, &b, &mut c);
        });
        println!("{}", st.report());
        records.push(BenchRecord::new("matmul_par", &format!("t{t}"), m, n, 0, st));
    }
    println!();

    // --- parallel optimizer step over a transformer-ish layer zoo --------
    let metas: Vec<LayerMeta> = (0..24)
        .map(|i| {
            let (r, c) = match i % 3 {
                0 => (512, 256),
                1 => (256, 512), // wide → transpose orientation
                _ => (256, 256),
            };
            LayerMeta::new(&format!("w{i}"), r, c, ParamKind::Linear)
        })
        .collect();
    let grads: Vec<Matrix> = metas
        .iter()
        .map(|meta| Matrix::randn(meta.rows, meta.cols, 0.1, &mut rng))
        .collect();
    for &t in &LANES {
        let mut opt = OptimizerSpec::dct_adamw(32).threads(Some(t)).build(&metas);
        let mut params: Vec<Matrix> = metas
            .iter()
            .map(|meta| Matrix::zeros(meta.rows, meta.cols))
            .collect();
        // warm the per-shard workspace pools before timing
        for _ in 0..3 {
            opt.step(&mut params, &grads, 1e-3);
        }
        let st = measure(&format!("dct_adamw_step t={t} L=24"), 1, 8, || {
            opt.step(&mut params, &grads, 1e-3);
        });
        println!("{}", st.report());
        records.push(BenchRecord::new("optim_step", &format!("t{t}"), 512, 256, 32, st));
    }
    println!();

    // --- threaded ring all-reduce ----------------------------------------
    let world = 8usize;
    let elems = 1 << 20;
    let base: Vec<Matrix> = (0..world)
        .map(|_| Matrix::randn(1, elems, 1.0, &mut rng))
        .collect();
    for &t in &LANES {
        let pool = Arc::new(ThreadPool::new(t));
        let mut comm = Communicator::with_pool(world, CommModel::default(), pool);
        let mut bufs = base.clone();
        let st = measure(&format!("all_reduce t={t} W={world} n={elems}"), 1, 8, || {
            comm.all_reduce_mean(&mut bufs);
        });
        println!("{}", st.report());
        records.push(BenchRecord::new("all_reduce", &format!("t{t}"), world, elems, 0, st));
    }

    let out = std::env::var("BENCH_PAR_OUT").unwrap_or_else(|_| "BENCH_PAR.json".into());
    match write_bench_json(&out, &records) {
        Ok(()) => println!("\nwrote {} records to {out}", records.len()),
        Err(e) => eprintln!("failed to write {out}: {e}"),
    }
}
