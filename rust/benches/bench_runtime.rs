//! Runtime bench: PJRT fwd/bwd step time per preset, per-phase breakdown,
//! and AOT-optimizer-graph vs rust-native optimizer step — the L2/L3
//! numbers in EXPERIMENTS.md §Perf.

use fft_subspace::bench::measure;
use fft_subspace::optim::Optimizer; // trait method `step` on AotOptimizer
use fft_subspace::optim::{build_optimizer, OptimizerKind};
use fft_subspace::runtime::client::Value;
use fft_subspace::runtime::{Manifest, Runtime};
use fft_subspace::tensor::Matrix;
use fft_subspace::train::aot_optim::AotOptimizer;
use fft_subspace::train::trainer::init_params;
use fft_subspace::train::TrainConfig;
use fft_subspace::util::Pcg64;

fn main() -> anyhow::Result<()> {
    println!("== bench_runtime (PJRT fwd/bwd + AOT optimizer graphs) ==\n");
    let manifest = Manifest::load(
        std::env::var("FFT_SUBSPACE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    )?;
    let rt = Runtime::new()?;
    let mut rng = Pcg64::seed(0);

    for preset in ["nano", "micro", "small"] {
        let spec = manifest.model_spec(preset)?;
        let exe = rt.load(manifest.find(&format!("fwdbwd_{preset}"))?)?;
        let params = init_params(&spec, 42);
        let tokens: Vec<i32> = (0..spec.batch_per_worker * spec.seq_len)
            .map(|_| rng.below(256) as i32)
            .collect();
        let shape = vec![spec.batch_per_worker, spec.seq_len];
        let stats = measure(&format!("fwdbwd_{preset} (B=8)"), 2, 8, || {
            let mut inputs: Vec<Value> =
                params.iter().map(|p| Value::F32(p.clone())).collect();
            inputs.push(Value::tokens(tokens.clone(), shape.clone()));
            exe.run(&inputs).unwrap()
        });
        let toks = (spec.batch_per_worker * spec.seq_len) as f64;
        println!(
            "{}  ({:.0} tok/s, {:.1}M params)",
            stats.report(),
            toks / stats.median_secs,
            spec.num_params as f64 / 1e6
        );
    }

    // AOT optimizer graph vs rust-native Trion on the micro shapes.
    println!("\nAOT trion graph vs rust-native trion (micro linear layers):");
    let spec = manifest.model_spec("micro")?;
    let metas: Vec<_> = spec.params.iter().map(|p| p.layer_meta()).collect();
    let mut cfg = TrainConfig::default();
    cfg.preset = "micro".into();
    cfg.optimizer = OptimizerKind::Trion;
    cfg.opt.rank = 32;
    let grads: Vec<Matrix> = metas
        .iter()
        .map(|m| Matrix::randn(m.rows, m.cols, 0.02, &mut rng))
        .collect();

    let mut aot = AotOptimizer::new(&metas, &cfg, &manifest, &rt, "trion")?;
    let mut p1: Vec<Matrix> = metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
    let s_aot = measure("trion step (AOT graphs via PJRT)", 1, 5, || {
        aot.step(&mut p1, &grads, 1e-3);
    });
    println!("{}  ({} layers on the AOT path)", s_aot.report(), aot.aot_layer_count());

    let mut native = build_optimizer(&OptimizerKind::Trion, &metas, &cfg.opt);
    let mut p2: Vec<Matrix> = metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
    let s_nat = measure("trion step (rust-native)", 1, 5, || {
        native.step(&mut p2, &grads, 1e-3);
    });
    println!("{}", s_nat.report());
    println!(
        "native/AOT ratio: {:.2}x",
        s_aot.median_secs / s_nat.median_secs
    );
    Ok(())
}
