//! SIMD on/off kernel sweep — the baseline for the perf trajectory of the
//! runtime-dispatched vector layer (`make bench-simd` → `BENCH_SIMD.json`,
//! override the path with `BENCH_SIMD_OUT=…`).
//!
//! Every group races the forced-scalar backend (the exact
//! `FFT_SUBSPACE_SIMD=0` code path) against the auto-detected backend on
//! the same buffers. The two are bit-identical by the `crate::simd`
//! contract (enforced in `tests/simd_bit_identity.rs`), so the printed
//! ratio is pure ALU/bandwidth speedup:
//!
//! * `matmul` / `matmul_at_b` / `matmul_a_bt` — the projection/update GEMMs
//! * `makhoul` — the split-butterfly DCT row transform (even + odd widths)
//! * `adam` — the fused dense AdamW elementwise kernel
//! * `col_norms` — the ℓ2 column accumulator behind selection
//! * `newton_schulz` — Trion's orthogonalization (matmul-bound)

use fft_subspace::bench::{
    measure, with_simd_backends, write_bench_json, BenchRecord, BenchStats,
};
use fft_subspace::fft::cached_plan;
use fft_subspace::linalg::newton_schulz_into;
use fft_subspace::optim::{adam_fused_update, AdamScalars};
use fft_subspace::simd::backend;
use fft_subspace::tensor::{
    matmul_a_bt_into, matmul_at_b_into, matmul_into, Matrix, Workspace,
};
use fft_subspace::util::Pcg64;

/// Run `f` under the forced-scalar and the auto backend (shared
/// `bench::with_simd_backends` driver); returns `[(variant, stats); 2]`.
fn race(
    name: &str,
    warmup: usize,
    iters: usize,
    mut f: impl FnMut(),
) -> Vec<(String, BenchStats)> {
    let mut legs: Vec<(String, BenchStats)> = Vec::new();
    with_simd_backends(|be| {
        let st = measure(&format!("{name} [{be}]"), warmup, iters, &mut f);
        println!("{}", st.report());
        legs.push((be.to_string(), st));
    });
    println!(
        "  simd speedup: {:.2}x\n",
        legs[0].1.median_secs / legs[1].1.median_secs
    );
    legs
}

fn push(
    records: &mut Vec<BenchRecord>,
    group: &str,
    rows: usize,
    cols: usize,
    rank: usize,
    raced: Vec<(String, BenchStats)>,
) {
    for (variant, stats) in raced {
        records.push(BenchRecord::new(group, &variant, rows, cols, rank, stats));
    }
}

fn main() {
    println!("== bench_simd (runtime-dispatched kernels, vector vs scalar) ==");
    println!("auto backend: {}\n", backend().name());
    let mut records: Vec<BenchRecord> = Vec::new();
    let mut rng = Pcg64::seed(0);
    let mut ws = Workspace::new();

    // --- matmul family ---------------------------------------------------
    for &(m, k, n) in &[(256usize, 256usize, 256usize), (1024, 512, 64)] {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let at = Matrix::randn(k, m, 1.0, &mut rng);
        let bt = Matrix::randn(n, k, 1.0, &mut rng);
        let mut c = ws.take(m, n);
        let r = race(&format!("matmul {m}x{k}x{n}"), 1, 10, || {
            matmul_into(&a, &b, &mut c)
        });
        push(&mut records, "matmul", m, n, k, r);
        let r = race(&format!("matmul_at_b {m}x{k}x{n}"), 1, 10, || {
            matmul_at_b_into(&at, &b, &mut c)
        });
        push(&mut records, "matmul_at_b", m, n, k, r);
        let r = race(&format!("matmul_a_bt {m}x{k}x{n}"), 1, 10, || {
            matmul_a_bt_into(&a, &bt, &mut c)
        });
        push(&mut records, "matmul_a_bt", m, n, k, r);
        ws.give(c);
    }

    // --- Makhoul DCT rows: split (even) and Bluestein (odd) --------------
    for &cols in &[512usize, 1024, 999] {
        let rows = 256;
        let g = Matrix::randn(rows, cols, 1.0, &mut rng);
        let plan = cached_plan(cols);
        let mut out = ws.take(rows, cols);
        let r = race(&format!("makhoul {rows}x{cols}"), 2, 10, || {
            plan.run_into(&g, &mut out)
        });
        push(&mut records, "makhoul", rows, cols, 0, r);
        ws.give(out);
    }

    // --- fused AdamW elementwise kernel ----------------------------------
    {
        let n = 1 << 20;
        let g: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let mut p = vec![0.5f32; n];
        let mut m = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        let sc = AdamScalars::new(0.9, 0.999, 1e-8, 10);
        let r = race("adam_fused 1M", 2, 20, || {
            adam_fused_update(&mut p, &g, &mut m, &mut v, 1e-3, 0.01, &sc)
        });
        push(&mut records, "adam", 1, n, 0, r);
    }

    // --- column norms (selection front half) -----------------------------
    {
        let m = Matrix::randn(1024, 1024, 1.0, &mut rng);
        let mut acc = vec![0.0f64; 1024];
        let r = race("col_sq_sums 1024x1024", 2, 20, || {
            m.col_sq_sums_into(&mut acc)
        });
        push(&mut records, "col_norms", 1024, 1024, 0, r);
    }

    // --- Newton–Schulz (Trion's per-step orthogonalization) --------------
    {
        let x = Matrix::randn(1024, 64, 1.0, &mut rng);
        let mut out = Matrix::zeros(0, 0);
        let r = race("newton_schulz 1024x64", 1, 10, || {
            newton_schulz_into(&x, 5, &mut out, &mut ws)
        });
        push(&mut records, "newton_schulz", 1024, 64, 64, r);
    }

    let out = std::env::var("BENCH_SIMD_OUT").unwrap_or_else(|_| "BENCH_SIMD.json".into());
    match write_bench_json(&out, &records) {
        Ok(()) => println!("wrote {} records to {out}", records.len()),
        Err(e) => eprintln!("failed to write {out}: {e}"),
    }
}
