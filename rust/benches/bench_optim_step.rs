//! End-to-end optimizer-step bench across the whole family at a fixed
//! synthetic model: the per-step optimizer cost columns behind Tables 1/2/6
//! (compute only — comm is bench_collectives, fwd/bwd is bench_runtime).

use fft_subspace::bench::measure;
use fft_subspace::optim::{
    build_optimizer, LayerMeta, OptimizerConfig, OptimizerKind, ParamKind,
};
use fft_subspace::tensor::Matrix;
use fft_subspace::util::Pcg64;

fn model(d: usize, layers: usize) -> Vec<LayerMeta> {
    let ff = d * 11 / 4;
    let mut metas = vec![LayerMeta::new("embed", 257, d, ParamKind::Embed)];
    for l in 0..layers {
        for w in ["wq", "wk", "wv", "wo"] {
            metas.push(LayerMeta::new(&format!("b{l}.{w}"), d, d, ParamKind::Linear));
        }
        metas.push(LayerMeta::new(&format!("b{l}.gate"), d, ff, ParamKind::Linear));
        metas.push(LayerMeta::new(&format!("b{l}.down"), ff, d, ParamKind::Linear));
    }
    metas.push(LayerMeta::new("head", d, 257, ParamKind::Head));
    metas
}

fn main() {
    println!("== bench_optim_step (per-step optimizer cost, d=128, 4 blocks) ==\n");
    let metas = model(128, 4);
    let mut rng = Pcg64::seed(0);
    let grads: Vec<Matrix> = metas
        .iter()
        .map(|m| Matrix::randn(m.rows, m.cols, 0.02, &mut rng))
        .collect();

    for rank in [16usize, 64] {
        println!("rank {rank}:");
        for kind in [
            OptimizerKind::AdamW,
            OptimizerKind::Muon,
            OptimizerKind::Dion,
            OptimizerKind::Trion,
            OptimizerKind::GaLore,
            OptimizerKind::LdAdamW,
            OptimizerKind::DctAdamW,
            OptimizerKind::Frugal,
            OptimizerKind::Fira,
        ] {
            let cfg = OptimizerConfig { rank, ..Default::default() };
            let mut opt = build_optimizer(&kind, &metas, &cfg);
            let mut params: Vec<Matrix> = metas
                .iter()
                .map(|m| Matrix::zeros(m.rows, m.cols))
                .collect();
            let stats = measure(&format!("{} r={rank}", kind.name()), 2, 8, || {
                opt.step(&mut params, &grads, 1e-3);
            });
            let mem = opt.memory_report().total();
            println!("{}  state={}", stats.report(), fft_subspace::util::human::bytes(mem));
        }
        println!();
    }
}
