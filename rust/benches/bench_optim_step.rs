//! End-to-end optimizer-step bench across the engine presets at a fixed
//! synthetic model: the per-step optimizer cost columns behind Tables 1/2/6
//! (compute only — comm is bench_collectives, fwd/bwd is bench_runtime).
//!
//! Emits `BENCH_OPTIM.json` (override with `BENCH_OPTIM_OUT=path`), one
//! group per preset (`dct-adamw`, `trion`, `galore`, `fira`, `frugal`,
//! `ldadamw`), each with variants `{low-rank, dense}/t{1,N}`:
//!
//! * `low-rank` — every layer eligible (hidden linears), the composed
//!   subspace step.
//! * `dense`   — the same shapes flagged non-eligible, so every layer takes
//!   the engine's dense-AdamW fallback.
//! * `t1` vs `tN` — sequential vs the parallel `step_layers_parallel` path
//!   (results are bit-identical; this records what the lanes buy).
//!
//! Run via `make bench-optim` in a toolchain-equipped environment.

use fft_subspace::bench::models::{linear_blocks, transformer_stack};
use fft_subspace::bench::{measure, write_bench_json, BenchRecord};
use fft_subspace::optim::{
    build_optimizer, LayerMeta, OptimizerConfig, OptimizerKind, ParamKind, StepPlanMode,
};
use fft_subspace::tensor::Matrix;
use fft_subspace::util::Pcg64;

/// Transformer-ish layer zoo (shared `bench::models` shapes); `kind` flips
/// between the low-rank path (Linear) and the dense-AdamW fallback (Head).
fn model(d: usize, layers: usize, kind: ParamKind) -> Vec<LayerMeta> {
    linear_blocks(d, layers, kind)
}

fn main() {
    let d = 128usize;
    let blocks = 4usize;
    let rank = 32usize;
    let lanes = [1usize, 4];
    println!(
        "== bench_optim_step (per-step cost, d={d}, {blocks} blocks, rank {rank}; \
         six engine presets × {{low-rank, dense}} × lanes {{1, 4}}) ==\n"
    );
    let mut records: Vec<BenchRecord> = Vec::new();

    for kind in [
        OptimizerKind::DctAdamW,
        OptimizerKind::Trion,
        OptimizerKind::GaLore,
        OptimizerKind::Fira,
        OptimizerKind::Frugal,
        OptimizerKind::LdAdamW,
    ] {
        for (variant, param_kind) in [("low-rank", ParamKind::Linear), ("dense", ParamKind::Head)]
        {
            let metas = model(d, blocks, param_kind);
            let mut rng = Pcg64::seed(0);
            let grads: Vec<Matrix> = metas
                .iter()
                .map(|m| Matrix::randn(m.rows, m.cols, 0.02, &mut rng))
                .collect();
            for &t in &lanes {
                // each preset at its published cadence: GaLore T_u=200 (so
                // its timed steps are the project-only steady state it
                // actually runs), everything else T_u=1 — DctAdamW/Fira/
                // Frugal refresh every timed step, which IS their default
                // per-step cost (Trion/LDAdamW pin T_u=1 regardless)
                let cfg = OptimizerConfig {
                    rank,
                    threads: Some(t),
                    update_interval: if kind == OptimizerKind::GaLore { 200 } else { 1 },
                    ..Default::default()
                };
                let mut opt = build_optimizer(&kind, &metas, &cfg);
                let mut params: Vec<Matrix> = metas
                    .iter()
                    .map(|m| Matrix::zeros(m.rows, m.cols))
                    .collect();
                // warm the per-shard workspace pools (and take the one-off
                // subspace refresh) outside the timed window
                for _ in 0..3 {
                    opt.step(&mut params, &grads, 1e-3);
                }
                let label = format!("{} {variant} t={t} r={rank}", kind.name());
                let stats = measure(&label, 2, 8, || {
                    opt.step(&mut params, &grads, 1e-3);
                });
                let mem = opt.memory_report().total();
                println!(
                    "{}  state={}",
                    stats.report(),
                    fft_subspace::util::human::bytes(mem)
                );
                records.push(BenchRecord::new(
                    kind.name(),
                    &format!("{variant}/t{t}"),
                    d,
                    d,
                    rank,
                    stats,
                ));
            }
        }
        println!();
    }

    // Many-layer stack: the shape-batched step-plan target. 24 repeated
    // transformer blocks (24× d×d attention, 24× d×ff gate, 24× ff×d down,
    // plus dense embed/head/norms) — fused vs interpreted per-step cost at
    // the published cadences, the compiled-plan headline rows.
    {
        let d = 64usize;
        let blocks = 24usize;
        let metas = transformer_stack(d, blocks, 256);
        let mut rng = Pcg64::seed(1);
        let grads: Vec<Matrix> = metas
            .iter()
            .map(|m| Matrix::randn(m.rows, m.cols, 0.02, &mut rng))
            .collect();
        println!(
            "== stack24 (d={d}, {blocks} blocks; fused vs interpreted step plans) =="
        );
        for kind in [OptimizerKind::DctAdamW, OptimizerKind::Trion, OptimizerKind::GaLore]
        {
            for plan in [StepPlanMode::Fused, StepPlanMode::Interpreted] {
                for &t in &lanes {
                    let cfg = OptimizerConfig {
                        rank,
                        threads: Some(t),
                        step_plan: plan,
                        update_interval: if kind == OptimizerKind::GaLore {
                            200
                        } else {
                            1
                        },
                        ..Default::default()
                    };
                    let mut opt = build_optimizer(&kind, &metas, &cfg);
                    let mut params: Vec<Matrix> = metas
                        .iter()
                        .map(|m| Matrix::zeros(m.rows, m.cols))
                        .collect();
                    for _ in 0..3 {
                        opt.step(&mut params, &grads, 1e-3);
                    }
                    let label =
                        format!("stack24 {} {} t={t}", kind.name(), plan.name());
                    let stats = measure(&label, 2, 8, || {
                        opt.step(&mut params, &grads, 1e-3);
                    });
                    println!("{}", stats.report());
                    records.push(BenchRecord::new(
                        &format!("stack24-{}", kind.name()),
                        &format!("{}/t{t}", plan.name()),
                        d,
                        d,
                        rank,
                        stats,
                    ));
                }
            }
        }
        println!();
    }

    let out =
        std::env::var("BENCH_OPTIM_OUT").unwrap_or_else(|_| "BENCH_OPTIM.json".into());
    match write_bench_json(&out, &records) {
        Ok(()) => println!("wrote {} records to {out}", records.len()),
        Err(e) => eprintln!("failed to write {out}: {e}"),
    }
}
