//! Telemetry overhead sweep — the PR-7 "obs=off costs ≤1%" budget as a
//! tracked artifact.
//!
//! Times steady-state engine steps (DctAdamW, the paper's method) under
//! each observability tier, sequential and parallel, and reports per-step
//! time plus the overhead ratio against the same configuration with
//! telemetry compiled to its disabled fast path. Under `trace` the timed
//! loop also drains the event rings every step, exactly like the trainer,
//! so the number is the real end-to-end cost and not just the span pushes.
//!
//! Emits `BENCH_OBS.json` (override with `BENCH_OBS_OUT=path`) via
//! `make bench-obs`. Wall-clock numbers vary by machine; the *ratios* are
//! the tracked quantity.

use std::time::Instant;

use fft_subspace::obs::{self, ObsTier};
use fft_subspace::optim::{
    build_optimizer, LayerMeta, Optimizer, OptimizerConfig, OptimizerKind, ParamKind,
};
use fft_subspace::tensor::Matrix;
use fft_subspace::util::json::{num, obj, s, Json};
use fft_subspace::util::Pcg64;

/// Small transformer-ish zoo: enough layers that the parallel path has
/// real chunks, small enough that a tier sweep finishes in seconds.
fn model(d: usize, blocks: usize) -> Vec<LayerMeta> {
    let mut metas = vec![LayerMeta::new("embed", 4 * d, d, ParamKind::Embed)];
    for l in 0..blocks {
        for w in ["wq", "wk", "wv", "wo"] {
            metas.push(LayerMeta::new(&format!("b{l}.{w}"), d, d, ParamKind::Linear));
        }
        metas.push(LayerMeta::new(&format!("b{l}.norm"), 1, d, ParamKind::Norm));
    }
    metas
}

fn main() {
    let metas = model(96, 4);
    let mut rng = Pcg64::seed(3);
    let grads: Vec<Matrix> = metas
        .iter()
        .map(|m| Matrix::randn(m.rows, m.cols, 0.1, &mut rng))
        .collect();
    let (warmup, timed) = (20usize, 120usize);

    println!(
        "== bench_obs (per-step telemetry overhead, DctAdamW rank 16, \
         {} layers, {timed} timed steps) ==\n",
        metas.len()
    );

    let mut records: Vec<Json> = Vec::new();
    for threads in [1usize, 4] {
        let mut off_ns = f64::NAN;
        for tier in [ObsTier::Off, ObsTier::Counters, ObsTier::Trace] {
            obs::set_tier(tier);
            obs::set_sample(1);
            obs::counters().reset();
            let cfg = OptimizerConfig {
                rank: 16,
                threads: Some(threads),
                update_interval: 4,
                ..Default::default()
            };
            let mut opt = build_optimizer(&OptimizerKind::DctAdamW, &metas, &cfg);
            let mut params: Vec<Matrix> =
                metas.iter().map(|m| Matrix::zeros(m.rows, m.cols)).collect();
            let mut events: Vec<obs::Event> = Vec::new();
            for step in 0..warmup {
                opt.step(&mut params, &grads, 1e-3);
                if tier == ObsTier::Trace {
                    events.clear();
                    opt.drain_events(&mut events);
                }
                let _ = step;
            }
            let t0 = Instant::now();
            for _ in 0..timed {
                opt.step(&mut params, &grads, 1e-3);
                if tier == ObsTier::Trace {
                    events.clear();
                    opt.drain_events(&mut events);
                }
            }
            let ns_per_step = t0.elapsed().as_nanos() as f64 / timed as f64;
            if tier == ObsTier::Off {
                off_ns = ns_per_step;
            }
            let overhead = ns_per_step / off_ns - 1.0;
            println!(
                "  threads={threads} obs={:<8} {:>12.0} ns/step  \
                 ({:+.2}% vs off)",
                tier.name(),
                ns_per_step,
                overhead * 100.0
            );
            records.push(obj(vec![
                ("optimizer", s("dct_adamw")),
                ("threads", num(threads as f64)),
                ("obs", s(tier.name())),
                ("timed_steps", num(timed as f64)),
                ("ns_per_step", num(ns_per_step)),
                ("steps_per_sec", num(1e9 / ns_per_step)),
                ("overhead_vs_off", num(overhead)),
            ]));
        }
        println!();
    }
    obs::set_tier(ObsTier::Off);

    let out = std::env::var("BENCH_OBS_OUT").unwrap_or_else(|_| "BENCH_OBS.json".into());
    let doc = obj(vec![("version", num(1.0)), ("records", Json::Arr(records))]);
    match std::fs::write(&out, doc.to_string()) {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("failed to write {out}: {e}"),
    }
}
