//! **Tables 4 & 5 + Appendix C/D**: Makhoul's FFT-based DCT vs matmul DCT.
//!
//! Paper shapes: (4096,4096) Llama-2-7B, (25600,5120) and (5120,25600)
//! Qwen3-32B, fp32 (Table 4) and bf16-storage matmul vs fp32 Makhoul
//! (Table 5). A scaled replica of each shape (÷8 per side, keeping the
//! aspect ratios and the R<C / R≥C split) runs by default so the bench
//! finishes on one CPU core; pass --full for the paper's exact shapes.
//!
//! Expected *shape* of the result (the claim under test): the FFT path
//! wins asymptotically and most dramatically when R < C (many short rows →
//! O(R·C log C) vs O(R·C²)), and a complexity fit over n confirms
//! O(n² log n) vs O(n³) growth.

use fft_subspace::bench::{fmt_secs, measure};
use fft_subspace::fft::{dct2_matrix, MakhoulPlan};
use fft_subspace::tensor::bf16::{matmul_bf16, Bf16Matrix};
use fft_subspace::tensor::{matmul, Matrix};
use fft_subspace::util::Pcg64;

fn bench_shape(rows: usize, cols: usize, label: &str) {
    let mut rng = Pcg64::seed(42);
    let g = Matrix::randn(rows, cols, 1.0, &mut rng);
    let q = dct2_matrix(cols);
    let plan = MakhoulPlan::new(cols);

    let iters = if rows * cols > 1_000_000 { 3 } else { 10 };
    let mm = measure(&format!("matmul_f32 {label}"), 1, iters, || matmul(&g, &q));
    let mk = measure(&format!("makhoul_f32 {label}"), 1, iters, || plan.run(&g));
    println!("{}", mm.report());
    println!("{}", mk.report());

    // Table 5: bf16-stored matmul with modeled 2× bf16 ALU throughput
    // (this CPU has no bf16 units; see DESIGN.md §Hardware-Adaptation).
    let gb = Bf16Matrix::from_f32(&g);
    let qb = Bf16Matrix::from_f32(&q);
    let mmb = measure(&format!("matmul_bf16 {label}"), 1, iters.min(3), || {
        matmul_bf16(&gb, &qb)
    });
    let bf16_speedup = 2.0;
    let mmb_modeled = mm.median_secs / bf16_speedup;
    println!(
        "{:<44} modeled {:>12} (storage-emulated raw {})",
        format!("matmul_bf16(modeled 2x) {label}"),
        fmt_secs(mmb_modeled),
        fmt_secs(mmb.median_secs)
    );
    println!(
        "  Table4 ratio (matmul_f32 / makhoul):        {:>8.2}x {}",
        mm.median_secs / mk.median_secs,
        if mm.median_secs > mk.median_secs { "(makhoul wins)" } else { "(matmul wins)" }
    );
    println!(
        "  Table5 ratio (matmul_bf16-modeled / makhoul): {:>6.2}x\n",
        mmb_modeled / mk.median_secs
    );
}

fn complexity_fit() {
    println!("complexity fit over n (Appendix C):");
    let mut rng = Pcg64::seed(1);
    let mut prev: Option<(f64, f64)> = None;
    for n in [128usize, 256, 512, 1024] {
        let g = Matrix::randn(64, n, 1.0, &mut rng);
        let q = dct2_matrix(n);
        let plan = MakhoulPlan::new(n);
        let mm = measure(&format!("matmul n={n}"), 1, 5, || matmul(&g, &q));
        let mk = measure(&format!("makhoul n={n}"), 1, 5, || plan.run(&g));
        let note = match prev {
            Some((pm, pk)) => format!(
                "growth: matmul {:.2}x (O(n²)→4x/double), makhoul {:.2}x (O(n log n)→~2.2x)",
                mm.median_secs / pm,
                mk.median_secs / pk
            ),
            None => String::new(),
        };
        println!(
            "  n={n:<5} matmul {:>11}  makhoul {:>11}  ratio {:>6.2}x  {note}",
            fmt_secs(mm.median_secs),
            fmt_secs(mk.median_secs),
            mm.median_secs / mk.median_secs
        );
        prev = Some((mm.median_secs, mk.median_secs));
    }
    println!();
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    println!("== bench_makhoul (Tables 4-5, Appendix C/D) ==\n");
    complexity_fit();
    if full {
        // the paper's exact shapes — minutes on one core
        bench_shape(4096, 4096, "(4096,4096) Llama-2-7B");
        bench_shape(25600, 5120, "(25600,5120) Qwen3-32B");
        bench_shape(5120, 25600, "(5120,25600) Qwen3-32B");
    } else {
        // 1/8-scale replicas with identical aspect ratios
        bench_shape(512, 512, "(512,512) ~ Llama-2-7B/8");
        bench_shape(3200, 640, "(3200,640) ~ Qwen3-32B/8  R>C");
        bench_shape(640, 3200, "(640,3200) ~ Qwen3-32B/8  R<C");
    }
}
