# fft-subspace — build / test / bench entry points.
#
# The rust workspace lives in rust/ and is fully offline (vendored
# anyhow/xla shims, no registry access). `make artifacts` needs the python
# side (jax) and writes the AOT HLO artifacts the PJRT runtime consumes.

CARGO ?= cargo
RUST_DIR := rust

.PHONY: build test test-faults test-matrix bench bench-proj bench-par bench-simd bench-makhoul bench-optim bench-mem bench-obs bench-comm artifacts clean

build:
	cd $(RUST_DIR) && $(CARGO) build --release

test:
	cd $(RUST_DIR) && $(CARGO) test -q

# Fault-injection suite on its own: guard skip/rollback bit-equality,
# torn-checkpoint recovery, worker-lane retry (tests/fault_recovery.rs).
test-faults:
	cd $(RUST_DIR) && $(CARGO) test -q --test fault_recovery

# The SIMD × threading conformance matrix: the whole suite under the scalar
# and vector kernel backends at 1 and 4 pool lanes. Results must be
# identical in every cell (the bit-identity + determinism contracts).
# The second loop sweeps the typed-storage axis over the engine suites:
# FFT_SUBSPACE_STATE_DTYPE drives the dtype the resume/alloc/parallel
# engine tests exercise (f32 is the bit-exact default, bf16 the staging
# path) — determinism and zero-allocation must hold for every dtype.
# The third loop sweeps the fault-injection axis: FFT_SUBSPACE_FAULT picks
# which deterministic fault the recovery suite injects (NaN vs +Inf, seeded
# vs pinned layer) — every cell must still converge to the fault-free bits.
# The fourth loop sweeps the observability axis: FFT_SUBSPACE_OBS at the
# extremes (off / trace) over the determinism + zero-allocation suites —
# telemetry must never change the bits or cost a steady-state allocation.
# The fifth loop sweeps the step-plan axis: FFT_SUBSPACE_STEP_PLAN runs the
# engine suites under the fused shape-batched group programs and under the
# interpreted per-layer oracle — resume, fault recovery, thread-count
# determinism and the fused-vs-interpreted equivalence suite must all hold
# in both cells.
# The sixth loop sweeps the gradient-sync axis: FFT_SUBSPACE_COMM runs the
# comm, resume and fault suites under dense and subspace-compressed
# collectives — compression must never change the bits of a fixed
# (world, comm) point nor break checkpoint/rollback recovery.
# The seventh loop sweeps the wire-format axis: FFT_SUBSPACE_WIRE runs the
# same suites with the compressed coefficient blocks shipped as raw f32 and
# as q8 (per-block scale + int8 payload, quantization error folded into the
# EF residual) — q8 must keep every determinism, resume and recovery
# contract of a fixed (world, comm, wire) point.
test-matrix:
	cd $(RUST_DIR) && for s in 0 1; do for t in 1 4; do \
		echo "== FFT_SUBSPACE_SIMD=$$s FFT_SUBSPACE_THREADS=$$t =="; \
		FFT_SUBSPACE_SIMD=$$s FFT_SUBSPACE_THREADS=$$t $(CARGO) test -q || exit 1; \
	done; done
	cd $(RUST_DIR) && for d in f32 bf16; do \
		echo "== FFT_SUBSPACE_STATE_DTYPE=$$d (engine suites) =="; \
		FFT_SUBSPACE_STATE_DTYPE=$$d $(CARGO) test -q \
			--test resume_determinism --test alloc_steady_state \
			--test parallel_determinism || exit 1; \
	done
	cd $(RUST_DIR) && for f in "grad-nan@3" "grad-inf@6.1" "grad-nan@4,seed@9"; do \
		echo "== FFT_SUBSPACE_FAULT=$$f (fault recovery) =="; \
		FFT_SUBSPACE_FAULT=$$f $(CARGO) test -q --test fault_recovery || exit 1; \
	done
	cd $(RUST_DIR) && for o in off trace; do \
		echo "== FFT_SUBSPACE_OBS=$$o (observability) =="; \
		FFT_SUBSPACE_OBS=$$o $(CARGO) test -q \
			--test obs_determinism --test alloc_steady_state || exit 1; \
	done
	cd $(RUST_DIR) && for p in fused interpreted; do \
		echo "== FFT_SUBSPACE_STEP_PLAN=$$p (step plans) =="; \
		FFT_SUBSPACE_STEP_PLAN=$$p $(CARGO) test -q \
			--test step_plan_equivalence --test resume_determinism \
			--test fault_recovery --test parallel_determinism || exit 1; \
	done
	cd $(RUST_DIR) && for c in dense subspace; do \
		echo "== FFT_SUBSPACE_COMM=$$c (gradient sync) =="; \
		FFT_SUBSPACE_COMM=$$c $(CARGO) test -q \
			--test comm_determinism --test resume_determinism \
			--test fault_recovery || exit 1; \
	done
	cd $(RUST_DIR) && for w in f32 q8; do \
		echo "== FFT_SUBSPACE_WIRE=$$w (wire format) =="; \
		FFT_SUBSPACE_WIRE=$$w $(CARGO) test -q \
			--test comm_determinism --test resume_determinism \
			--test fault_recovery || exit 1; \
	done

# Full microbench battery (each bench is a plain binary: harness = false).
bench: bench-proj bench-par bench-simd bench-makhoul bench-optim bench-mem bench-obs bench-comm

# Projection/subspace-step bench; writes rust/BENCH_PROJ.json
# (override the path with BENCH_PROJ_OUT=...). Includes the `threads`
# sweep group (1/2/4/8-lane similarity + dct_step).
bench-proj:
	cd $(RUST_DIR) && $(CARGO) bench --bench bench_projection

# Parallel-engine sweep (matmul / optimizer step / all-reduce per lane
# count); writes rust/BENCH_PAR.json (override with BENCH_PAR_OUT=...).
bench-par:
	cd $(RUST_DIR) && $(CARGO) bench --bench bench_parallel

# SIMD on/off kernel sweep (matmul family / Makhoul / fused Adam / column
# norms / Newton-Schulz); writes rust/BENCH_SIMD.json (override with
# BENCH_SIMD_OUT=...).
bench-simd:
	cd $(RUST_DIR) && $(CARGO) bench --bench bench_simd

bench-makhoul:
	cd $(RUST_DIR) && $(CARGO) bench --bench bench_makhoul

# Engine-preset optimizer-step sweep (six presets × {dense fallback,
# low-rank} × 1 vs 4 lanes), plus the stack24 group: a 24-block transformer
# stack timed under step-plan fused vs interpreted — the compiled-plan
# headline rows; writes rust/BENCH_OPTIM.json (override with
# BENCH_OPTIM_OUT=...).
bench-optim:
	cd $(RUST_DIR) && $(CARGO) bench --bench bench_optim_step

# Optimizer-state memory sweep (exact bytes: six presets × state-dtype
# {f32,bf16,q8} × two model sizes vs the dense Adam f32 baseline — the
# paper's ≤25%-memory claim as an artifact); writes rust/BENCH_MEM.json
# (override with BENCH_MEM_OUT=...). Deterministic byte counts, no timing.
bench-mem:
	cd $(RUST_DIR) && $(CARGO) bench --bench bench_mem

# Telemetry overhead sweep (per-step time under obs={off,counters,trace},
# 1 vs 4 lanes; the off→counters delta must stay within the ≤1% budget);
# writes rust/BENCH_OBS.json (override with BENCH_OBS_OUT=...).
bench-obs:
	cd $(RUST_DIR) && $(CARGO) bench --bench bench_obs

# Collectives + gradient-sync sweep (ring all-reduce, ZeRO broadcast
# volume, dense vs subspace×{f32,q8} sync bytes / T_u-amortized modeled
# α–β time / wall time per world size, plus the sequential-vs-overlapped
# refresh-boundary reduce); writes rust/BENCH_COLLECTIVES.json (override
# with BENCH_COLLECTIVES_OUT=...). The wire sweep is explicit in the bench,
# so one run covers every format.
bench-comm:
	cd $(RUST_DIR) && $(CARGO) bench --bench bench_collectives

# Lower the JAX/Pallas graphs to HLO text + manifest (Layer 1+2 → Layer 3
# contract). Requires jax; see python/compile/aot.py --help for presets.
artifacts:
	cd python && python -m compile.aot --out-dir ../$(RUST_DIR)/artifacts

clean:
	cd $(RUST_DIR) && $(CARGO) clean
