# fft-subspace — build / test / bench entry points.
#
# The rust workspace lives in rust/ and is fully offline (vendored
# anyhow/xla shims, no registry access). `make artifacts` needs the python
# side (jax) and writes the AOT HLO artifacts the PJRT runtime consumes.

CARGO ?= cargo
RUST_DIR := rust

.PHONY: build test bench bench-proj bench-par bench-makhoul bench-optim artifacts clean

build:
	cd $(RUST_DIR) && $(CARGO) build --release

test:
	cd $(RUST_DIR) && $(CARGO) test -q

# Full microbench battery (each bench is a plain binary: harness = false).
bench: bench-proj bench-par bench-makhoul bench-optim

# Projection/subspace-step bench; writes rust/BENCH_PROJ.json
# (override the path with BENCH_PROJ_OUT=...). Includes the `threads`
# sweep group (1/2/4/8-lane similarity + dct_step).
bench-proj:
	cd $(RUST_DIR) && $(CARGO) bench --bench bench_projection

# Parallel-engine sweep (matmul / optimizer step / all-reduce per lane
# count); writes rust/BENCH_PAR.json (override with BENCH_PAR_OUT=...).
bench-par:
	cd $(RUST_DIR) && $(CARGO) bench --bench bench_parallel

bench-makhoul:
	cd $(RUST_DIR) && $(CARGO) bench --bench bench_makhoul

bench-optim:
	cd $(RUST_DIR) && $(CARGO) bench --bench bench_optim_step

# Lower the JAX/Pallas graphs to HLO text + manifest (Layer 1+2 → Layer 3
# contract). Requires jax; see python/compile/aot.py --help for presets.
artifacts:
	cd python && python -m compile.aot --out-dir ../$(RUST_DIR)/artifacts

clean:
	cd $(RUST_DIR) && $(CARGO) clean
