"""Pure-jnp reference oracle for every Layer-1 kernel and Layer-2 graph.

This file is the single source of numerical truth for the whole repo:

* the Pallas kernels in this package are pytest-compared against it,
* the AOT optimizer graphs in ``optim_graphs.py`` are built from it,
* the rust-native implementations (``rust/src/{fft,linalg,projection,optim}``)
  are integration-tested against HLO artifacts lowered from these functions.

Everything here follows the paper:

* DCT-II/III matrices per Appendix A,
* Makhoul's N-point fast DCT-II per Appendix D,
* dynamic column selection per §2.1 / Appendix B,
* Trion per Algorithm 1, DCT-AdamW per Algorithms 2–3,
* Newton–Schulz with the Muon quintic coefficients.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# Muon's quintic Newton–Schulz coefficients (Jordan et al., 2024).
NS_COEFFS = (3.4445, -4.7750, 2.0315)


# ---------------------------------------------------------------------------
# DCT matrices (Appendix A)
# ---------------------------------------------------------------------------

def dct3_matrix(n: int, dtype=jnp.float32) -> jnp.ndarray:
    """Orthogonal DCT-III matrix ``D`` with ``D[i,j] = sqrt(2/n)·cos(i(2j+1)π/2n)``
    and the first row divided by ``sqrt(2)`` (Appendix A).

    Built exactly as the paper describes: an index column vector ``L`` is
    broadcast into ``I`` and the integer products ``i·(2j+1)`` are formed
    elementwise before a single ``cos``.
    """
    i = jnp.arange(n, dtype=jnp.float32)[:, None]          # I (replicated L)
    j = jnp.arange(n, dtype=jnp.float32)[None, :]          # I^T
    q = jnp.sqrt(2.0 / n) * jnp.cos(i * (2.0 * j + 1.0) * jnp.pi / (2.0 * n))
    q = q.at[0, :].divide(jnp.sqrt(2.0))
    return q.astype(dtype)


def dct2_matrix(n: int, dtype=jnp.float32) -> jnp.ndarray:
    """Orthogonal DCT-II matrix: the transpose of DCT-III (Appendix A).

    With ``Q = dct2_matrix(C)``, the similarity matrix of §2.1 is ``S = G·Q``
    and ``S[:, k]`` is the k-th DCT-II coefficient of every row of ``G`` —
    i.e. ``S`` *is* the row-wise type-II DCT of ``G``.
    """
    return dct3_matrix(n, dtype=dtype).T


# ---------------------------------------------------------------------------
# Makhoul's N-point fast DCT-II (Appendix D)
# ---------------------------------------------------------------------------

def makhoul_permute(x: jnp.ndarray) -> jnp.ndarray:
    """Step 1: ``[a,b,c,d,e,f] -> [a,c,e,f,d,b]`` — even indices ascending,
    odd indices descending, applied to the last axis."""
    even = x[..., 0::2]
    odd = x[..., 1::2]
    return jnp.concatenate([even, odd[..., ::-1]], axis=-1)


def makhoul_dct2(g: jnp.ndarray) -> jnp.ndarray:
    """Row-wise orthonormal DCT-II of ``g`` via Makhoul's N-point algorithm.

    Steps (Appendix D): permute -> FFT -> multiply by the Fourier
    coefficients ``W_k = exp(-iπk/2N)`` -> real part -> orthonormal scaling.
    Equivalent to ``g @ dct2_matrix(N)`` (the matmul "embeds" all of these
    steps in the DCT matrix itself, at O(n³) instead of O(n² log n)).
    """
    n = g.shape[-1]
    v = makhoul_permute(g)
    vf = jnp.fft.fft(v.astype(jnp.complex64), axis=-1)
    k = jnp.arange(n, dtype=jnp.float32)
    w = jnp.exp(-1j * jnp.pi * k / (2.0 * n))
    x = jnp.real(vf * w)                                    # unnormalized DCT-II
    scale = jnp.full((n,), jnp.sqrt(2.0 / n), dtype=jnp.float32)
    scale = scale.at[0].set(jnp.sqrt(1.0 / n))
    return (x * scale).astype(g.dtype)


# ---------------------------------------------------------------------------
# Dynamic column selection (§2.1, Appendix B)
# ---------------------------------------------------------------------------

def column_norms(s: jnp.ndarray, norm: str = "l2") -> jnp.ndarray:
    """Per-column ℓ1 or ℓ2 norms of the similarity matrix ``S``."""
    if norm == "l1":
        return jnp.sum(jnp.abs(s), axis=0)
    if norm == "l2":
        return jnp.sqrt(jnp.sum(s * s, axis=0))
    raise ValueError(f"unknown norm {norm!r}")


def dynamic_column_selection(s: jnp.ndarray, r: int, norm: str = "l2") -> jnp.ndarray:
    """Indices of the ``r`` columns of ``S`` with the largest norm,
    returned in ascending index order (deterministic tie-break by index).

    Implemented with a stable argsort rather than ``lax.top_k``: the AOT
    path needs HLO the rust-side XLA (0.5.1) text parser accepts, and the
    newer ``topk`` op is not in its grammar — ``sort`` is.
    """
    scores = column_norms(s, norm)
    order = jnp.argsort(-scores, stable=True)   # descending, ties → low index
    return jnp.sort(order[:r])


def dct_project(g: jnp.ndarray, q: jnp.ndarray, r: int, norm: str = "l2"):
    """Two-step procedure of §2.1: similarities + column selection.

    Returns ``(idx, low_rank, q_r)`` where ``low_rank = S[:, idx] = G·Q_r``.
    """
    s = g @ q
    idx = dynamic_column_selection(s, r, norm)
    return idx, s[:, idx], q[:, idx]


# ---------------------------------------------------------------------------
# Newton–Schulz orthogonalization (Muon / §2.3)
# ---------------------------------------------------------------------------

def newton_schulz(x: jnp.ndarray, steps: int = 5, eps: float = 1e-7) -> jnp.ndarray:
    """Quintic Newton–Schulz iteration pushing singular values of ``x`` to 1.

    Operates in the economical orientation: for a tall ``R×r`` input the
    Gram matrix ``A = XᵀX`` is only ``r×r`` — this is exactly the saving
    Trion exploits by feeding the *low-rank* momentum ``b_t`` instead of the
    full ``B_t`` (Algorithm 1, line 11).
    """
    a, b, c = NS_COEFFS
    transposed = x.shape[0] < x.shape[1]
    if transposed:
        x = x.T
    x = x / (jnp.linalg.norm(x) + eps)
    for _ in range(steps):
        gram = x.T @ x                       # r×r
        poly = b * gram + c * (gram @ gram)  # bA + cA²
        x = a * x + x @ poly
    return x.T if transposed else x


# ---------------------------------------------------------------------------
# AdamW (fused update kernel oracle)
# ---------------------------------------------------------------------------

def adamw_update(p, g, m, v, *, lr, beta1=0.9, beta2=0.999, eps=1e-8,
                 weight_decay=0.0, step=1):
    """One decoupled-weight-decay Adam step; returns ``(p', m', v')``."""
    m = beta1 * m + (1.0 - beta1) * g
    v = beta2 * v + (1.0 - beta2) * g * g
    mhat = m / (1.0 - beta1 ** step)
    vhat = v / (1.0 - beta2 ** step)
    p = (1.0 - lr * weight_decay) * p - lr * mhat / (jnp.sqrt(vhat) + eps)
    return p, m, v


# ---------------------------------------------------------------------------
# Trion per-layer update (Algorithm 1, lines 4–13)
# ---------------------------------------------------------------------------

def trion_layer_update(m_prev, g, q, *, rank: int, mu: float = 0.95,
                       ns_steps: int = 5, norm: str = "l2"):
    """One Trion step for a single layer with right-projection.

    Returns ``(m_new, o_full, idx)``:
    ``B_t = M_{t-1} + G_t``; ``S_t = B_t·Q``; top-r columns ``i_t``;
    ``b_t = S[:, i_t]``, ``Q_t = Q[:, i_t]``; error-feedback momentum
    ``M_t = B_t − (1−μ)·b_t·Q_tᵀ``; update ``O_t = NS(b_t)·Q_tᵀ``.
    The caller applies ``θ ← (1−λη)θ − η·max(1, sqrt(R/C))·O_t``.
    """
    b_full = m_prev + g
    s = b_full @ q
    idx = dynamic_column_selection(s, rank, norm)
    b_low = s[:, idx]
    q_r = q[:, idx]
    m_new = b_full - (1.0 - mu) * (b_low @ q_r.T)
    o_low = newton_schulz(b_low, steps=ns_steps)
    o_full = o_low @ q_r.T
    return m_new, o_full, idx


def mgs_qr(z: jnp.ndarray) -> jnp.ndarray:
    """Orthonormal basis of ``z``'s columns via modified Gram–Schmidt.

    Pure-jnp so the lowered HLO contains only elementwise/dot ops:
    ``jnp.linalg.qr`` lowers to a typed-FFI LAPACK custom-call that the
    rust-side XLA 0.5.1 cannot execute. Spans (hence Dion's trajectory,
    which is column-sign-invariant) match Householder QR.
    """
    n, r = z.shape
    cols = []
    for j in range(r):
        v = z[:, j]
        for qv in cols:
            v = v - qv * jnp.dot(qv, v)
        cols.append(v / (jnp.linalg.norm(v) + 1e-8))
    return jnp.stack(cols, axis=1)


def dion_layer_update(m_prev, g, q_prev, *, mu: float = 0.95):
    """One Dion step (Ahn et al., 2025) — the baseline Trion replaces.

    Power-iteration with QR: ``P_t = QR(B_t·Q_{t-1})`` (left basis, R×r),
    ``R_t = B_tᵀ·P_t`` (C×r), error feedback
    ``M_t = B_t − (1−μ)·P_t·R_tᵀ``, update
    ``O_t = P_t·column_normalize(R_t)ᵀ``, and the *persistent state* is the
    column-normalized right factor ``Q_t = colnorm(R_t) ∈ R^{C×r}`` fed to
    the next power-iteration. Returns ``(m_new, o_full, q_new)``.
    """
    b_full = m_prev + g
    z = b_full @ q_prev                       # R×r
    p_new = mgs_qr(z)                         # orthonormal R×r basis (left)
    r_mat = b_full.T @ p_new                  # C×r
    m_new = b_full - (1.0 - mu) * (p_new @ r_mat.T)
    q_new = r_mat / (jnp.linalg.norm(r_mat, axis=0, keepdims=True) + 1e-8)
    o_full = p_new @ q_new.T
    return m_new, o_full, q_new


# ---------------------------------------------------------------------------
# DCT-AdamW per-layer update (Algorithms 2–3, right projection, T_u = 1)
# ---------------------------------------------------------------------------

def dct_adamw_layer_update(g, q, m, v, ef, idx_prev, *, rank: int,
                           lr: float, beta1=0.9, beta2=0.999, eps=1e-8,
                           step=1, norm: str = "l2", first: bool = False):
    """One DCT-AdamW step for a single layer (subspace updated every step).

    ``G ← G + Ξ``; select new subspace from ``S = G·Q``; rotate the moment
    buffers with ``R = Q_prevᵀ·Q_crt`` (computed directly in the
    r-dimensional space); AdamW math in the subspace; back-project the
    update; store the projection residual in the error-feedback buffer.

    Returns ``(update_full, m', v', Ξ', idx')`` — the caller applies
    ``θ ← (1 − λη)θ − update_full``.
    """
    g = g + ef
    s = g @ q
    idx = dynamic_column_selection(s, rank, norm)
    q_crt = q[:, idx]
    if first:
        rot = jnp.eye(rank, dtype=g.dtype)
    else:
        rot = q[:, idx_prev].T @ q_crt        # r×r rotation between subspaces
    g_low = s[:, idx]                         # G·Q_crt
    ef_new = g - g_low @ q_crt.T
    m = beta1 * (m @ rot) + (1.0 - beta1) * g_low
    v = beta2 * jnp.abs(v @ rot) + (1.0 - beta2) * g_low * g_low
    mhat = m / (1.0 - beta1 ** step)
    vhat = v / (1.0 - beta2 ** step)
    u_low = lr * mhat / (jnp.sqrt(vhat) + eps)
    update_full = u_low @ q_crt.T
    return update_full, m, v, ef_new, idx


# ---------------------------------------------------------------------------
# 8-bit error-feedback quantization oracle (§2.4 / MicroAdam-style)
# ---------------------------------------------------------------------------

def quantize_ef_u8(x: jnp.ndarray):
    """Symmetric per-tensor 8-bit quantization: returns ``(q_u8, scale)``."""
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_ef_u8(q: jnp.ndarray, scale) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# Reconstruction error (§4.1) — used by the contractiveness tests
# ---------------------------------------------------------------------------

def reconstruction_error_sq(g: jnp.ndarray, q_r: jnp.ndarray) -> jnp.ndarray:
    """``‖G − Q_r·Q_rᵀ·G‖²_F`` for a left-projection (orthonormal ``Q_r``)."""
    proj = q_r @ (q_r.T @ g)
    d = g - proj
    return jnp.sum(d * d)


@functools.lru_cache(maxsize=32)
def cached_dct2(n: int):
    """Build-time convenience cache for repeated test shapes."""
    return dct2_matrix(n)
