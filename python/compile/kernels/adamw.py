"""Layer-1 Pallas kernel: fused AdamW moment + parameter update.

One row-tiled pass over (p, g, m, v): both moment updates, bias correction,
decoupled weight decay and the parameter step happen in VMEM, so each buffer
is read and written exactly once per step instead of the ~9 HBM round-trips
an unfused elementwise chain would cost. This is the low-rank AdamW inner
update used by DCT-AdamW (Algorithm 2, lines 11–13) where the operands are
the ``n×r`` subspace buffers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256


def _adamw_kernel(p_ref, g_ref, m_ref, v_ref, t_ref,
                  p_out, m_out, v_out,
                  *, lr, beta1, beta2, eps, weight_decay):
    g = g_ref[...]
    m = beta1 * m_ref[...] + (1.0 - beta1) * g
    v = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    t = t_ref[0]
    mhat = m / (1.0 - beta1 ** t)
    vhat = v / (1.0 - beta2 ** t)
    p = (1.0 - lr * weight_decay) * p_ref[...] - lr * mhat / (jnp.sqrt(vhat) + eps)
    p_out[...] = p
    m_out[...] = m
    v_out[...] = v


@functools.partial(jax.jit,
                   static_argnames=("lr", "beta1", "beta2", "eps", "weight_decay"))
def adamw_update(p, g, m, v, step, *, lr, beta1=0.9, beta2=0.999,
                 eps=1e-8, weight_decay=0.0):
    """Fused AdamW step over a 2-D tensor; returns ``(p', m', v')``.

    ``step`` is a float32 scalar array (1-based) for bias correction.
    """
    rows, cols = p.shape
    br = min(BLOCK_ROWS, rows)
    pad = (rows + br - 1) // br * br - rows
    def padr(x):
        return jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    t = jnp.reshape(step.astype(jnp.float32), (1,))
    outs = pl.pallas_call(
        functools.partial(_adamw_kernel, lr=lr, beta1=beta1, beta2=beta2,
                          eps=eps, weight_decay=weight_decay),
        grid=((rows + pad) // br,),
        in_specs=[
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct(((rows + pad), cols), p.dtype)] * 3,
        interpret=True,
    )(padr(p), padr(g), padr(m), padr(v), t)
    return tuple(o[:rows] for o in outs)
