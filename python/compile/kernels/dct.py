"""Layer-1 Pallas kernels for the DCT similarity hot-spot.

The paper's per-step hot path is ``S = G·Q`` (the row-wise DCT of the
gradient/momentum) followed by a column-norm ranking. On GPU the authors use
cuBLAS / cuFFT; on TPU the natural mapping (DESIGN.md §Hardware-Adaptation)
is an MXU-tiled matmul whose epilogue *fuses the column-norm accumulation*,
so the similarity matrix is written once to HBM and the ranking statistics
never require a second pass.

Kernels:

* ``dct_similarity``        — tiled ``S = G·Q`` (bm×bn×bk MXU tiles).
* ``dct_similarity_norms``  — same matmul with a fused ℓ1/ℓ2 column-norm
                              accumulator epilogue.
* ``gather_columns``        — ``S[:, idx]`` / ``Q[:, idx]`` tile-wise gather.

All kernels run under ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); block shapes are still chosen as if for real TPU VMEM/MXU —
see DESIGN.md §Perf for the footprint/utilization estimates.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# MXU-shaped default tiles. For the paper's shapes (C = d_model ≤ 4096,
# R up to 25600) this keeps the VMEM working set at
# bm·bk + bk·bn + bm·bn floats = 3·128² ·4B = 196KB ≪ 16MB.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _matmul_kernel(g_ref, q_ref, s_ref, acc_ref, *, n_k: int):
    """Grid (i, j, k): accumulate ``G[i,k]·Q[k,j]`` into an f32 VMEM scratch,
    flushing to the output tile on the last k-step."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        g_ref[...], q_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        s_ref[...] = acc_ref[...].astype(s_ref.dtype)


def _pad_dim(n: int, b: int) -> int:
    return (n + b - 1) // b * b


def _padded(x: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def dct_similarity(g: jnp.ndarray, q: jnp.ndarray,
                   bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                   bk: int = DEFAULT_BK) -> jnp.ndarray:
    """Tiled Pallas matmul ``S = G·Q`` (the row-wise DCT of ``G`` when ``Q``
    is the DCT-II matrix). Pads to tile multiples and slices back."""
    m, kdim = g.shape
    k2, n = q.shape
    assert kdim == k2, (g.shape, q.shape)
    bm, bn, bk = min(bm, _pad_dim(m, 8)), min(bn, _pad_dim(n, 8)), min(bk, _pad_dim(kdim, 8))
    mp, np_, kp = _pad_dim(m, bm), _pad_dim(n, bn), _pad_dim(kdim, bk)
    gp, qp = _padded(g, mp, kp), _padded(q, kp, np_)
    n_k = kp // bk
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), g.dtype),
        # f32 accumulator tile lives in VMEM across the k-loop
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(gp, qp)
    return out[:m, :n]


def _matmul_norms_kernel(g_ref, q_ref, s_ref, norms_ref, acc_ref,
                         *, n_k: int, n_i: int, norm: str):
    """Fused epilogue: on the final k-step of each (i, j) tile, add the
    tile's per-column ℓ1 (or squared-ℓ2) partials into the norm vector.

    The grid iterates k fastest, then j, then i — so tile (i, j) is final
    exactly once; ``norms`` is initialized on the first visit of each j.
    """
    i, k = pl.program_id(0), pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        g_ref[...], q_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        tile = acc_ref[...]
        s_ref[...] = tile.astype(s_ref.dtype)
        if norm == "l1":
            part = jnp.sum(jnp.abs(tile), axis=0)
        else:  # squared-l2 partials; sqrt applied by the caller
            part = jnp.sum(tile * tile, axis=0)

        @pl.when(i == 0)
        def _first_row_of_tiles():
            norms_ref[...] = part[None, :]

        @pl.when(i != 0)
        def _accumulate():
            norms_ref[...] += part[None, :]


@functools.partial(jax.jit, static_argnames=("norm", "bm", "bn", "bk"))
def dct_similarity_norms(g: jnp.ndarray, q: jnp.ndarray, norm: str = "l2",
                         bm: int = DEFAULT_BM, bn: int = DEFAULT_BN,
                         bk: int = DEFAULT_BK):
    """Fused ``S = G·Q`` + per-column norms in a single HBM pass.

    Returns ``(S, col_norms)`` — the inputs to dynamic column selection.
    """
    m, kdim = g.shape
    _, n = q.shape
    bm, bn, bk = min(bm, _pad_dim(m, 8)), min(bn, _pad_dim(n, 8)), min(bk, _pad_dim(kdim, 8))
    mp, np_, kp = _pad_dim(m, bm), _pad_dim(n, bn), _pad_dim(kdim, bk)
    gp, qp = _padded(g, mp, kp), _padded(q, kp, np_)
    n_k, n_i = kp // bk, mp // bm
    s, norms = pl.pallas_call(
        functools.partial(_matmul_norms_kernel, n_k=n_k, n_i=n_i, norm=norm),
        grid=(n_i, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, np_), g.dtype),
            jax.ShapeDtypeStruct((1, np_), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(gp, qp)
    norms = norms[0, :n]
    if norm == "l2":
        norms = jnp.sqrt(norms)
    return s[:m, :n], norms


def _gather_kernel(src_ref, idx_ref, out_ref):
    """Gather selected columns: each grid row-tile copies ``src[:, idx]``."""
    idx = idx_ref[...]
    out_ref[...] = jnp.take(src_ref[...], idx, axis=1)


@jax.jit
def gather_columns(src: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """``src[:, idx]`` as a row-tiled Pallas gather (used for ``S[:, i_t]``
    and ``Q[:, i_t]``)."""
    m, n = src.shape
    r = idx.shape[0]
    bm = min(DEFAULT_BM, _pad_dim(m, 8))
    mp = _pad_dim(m, bm)
    srcp = _padded(src, mp, n)
    out = pl.pallas_call(
        _gather_kernel,
        grid=(mp // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((r,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, r), src.dtype),
        interpret=True,
    )(srcp, idx)
    return out[:m]
