"""Layer-1 Pallas kernel: quintic Newton–Schulz orthogonalization.

Trion's key structural saving (Algorithm 1, line 11) is that Newton–Schulz
runs on the *low-rank* momentum ``b_t ∈ R^{R×r}`` rather than the full
``B_t ∈ R^{R×C}``; the Gram matrix is only ``r×r``. For the ranks the paper
uses (r ≤ 512) the whole iteration state fits in VMEM:

    X (R×r) + A (r×r) + poly (r×r)  ≤  1024·512·4B + 2·512²·4B ≈ 4.2 MB

so the kernel holds ``X`` resident and performs all ``steps`` iterations
without touching HBM — every matmul is MXU-shaped (r is a multiple of 128
in the paper's configurations).

On GPU the authors call Muon's triton kernels; this is the TPU rethink
(DESIGN.md §Hardware-Adaptation): one kernel, one HBM read, one HBM write.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref

# Keep single-block NS inputs within a conservative VMEM budget.
VMEM_BUDGET_FLOATS = 2 * 1024 * 1024  # 8 MB of f32


def _ns_kernel(x_ref, o_ref, *, steps: int, eps: float, transposed: bool):
    """All-in-VMEM quintic Newton–Schulz; ``transposed`` handles wide inputs
    (R < r) by iterating on ``Xᵀ`` so the Gram side stays the small one."""
    a, b, c = ref.NS_COEFFS
    x = x_ref[...]
    if transposed:
        x = x.T
    x = x / (jnp.sqrt(jnp.sum(x * x)) + eps)
    for _ in range(steps):
        gram = jnp.dot(x.T, x, preferred_element_type=jnp.float32)
        poly = b * gram + c * jnp.dot(gram, gram,
                                      preferred_element_type=jnp.float32)
        x = a * x + jnp.dot(x, poly, preferred_element_type=jnp.float32)
    if transposed:
        x = x.T
    o_ref[...] = x.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("steps",))
def newton_schulz(x: jnp.ndarray, steps: int = 5, eps: float = 1e-7) -> jnp.ndarray:
    """Pallas single-block Newton–Schulz. Falls back to the jnp reference
    when the input exceeds the VMEM budget (never the case for the paper's
    low-rank inputs)."""
    m, n = x.shape
    if m * n > VMEM_BUDGET_FLOATS:
        return ref.newton_schulz(x, steps=steps, eps=eps)
    return pl.pallas_call(
        functools.partial(_ns_kernel, steps=steps, eps=eps, transposed=m < n),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x)
