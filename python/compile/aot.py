"""AOT export: lower every Layer-2 graph to HLO text + write the manifest.

Interchange format is HLO *text*, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the rust ``xla`` crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See ``/opt/xla-example/README.md``.

Artifacts produced (``make artifacts``):

* ``fwdbwd_<preset>.hlo.txt``   — (params…, tokens) → (loss, grads…)
* ``eval_<preset>.hlo.txt``     — (params…, tokens) → (loss,)
* ``trion_<R>x<C>_r<r>.hlo.txt``      — per distinct linear-layer shape
* ``dctadamw_<R>x<C>_r<r>.hlo.txt``   — per distinct linear-layer shape
* ``dion_<R>x<C>_r<r>.hlo.txt``       — baseline graph (cross-checks)
* ``kernel_*.hlo.txt``          — L1 kernel smoke artifacts for rust tests
* ``manifest.json``             — shapes/dtypes/order for every artifact

The manifest is the contract with ``rust/src/runtime/artifacts.rs``.
"""

from __future__ import annotations

import argparse
import json
import math
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import optim_graphs as OG
from .kernels import ref

F32 = "f32"
I32 = "i32"


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _io(name, shape, dtype=F32):
    return {"name": name, "shape": list(shape), "dtype": dtype}


class Exporter:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries = []
        os.makedirs(out_dir, exist_ok=True)

    def export(self, name: str, fn, arg_specs, inputs, outputs, kind: str,
               meta=None):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        self.entries.append({
            "name": name, "file": fname, "kind": kind,
            "inputs": inputs, "outputs": outputs, "meta": meta or {},
        })
        print(f"  [{time.time()-t0:6.1f}s] {fname}  ({len(text)//1024} KiB)",
              flush=True)

    def write_manifest(self, extra):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump({"artifacts": self.entries, **extra}, f, indent=1)
        print(f"wrote {path} ({len(self.entries)} artifacts)")


def export_model_graphs(ex: Exporter, preset: str, batch_per_worker: int):
    cfg = M.PRESETS[preset]
    specs = M.param_specs(cfg)
    p_specs = [spec(s.shape) for s in specs]
    tok = spec((batch_per_worker, cfg.seq_len), jnp.int32)
    p_io = [_io(s.name, s.shape) for s in specs]
    tok_io = _io("tokens", (batch_per_worker, cfg.seq_len), I32)
    grads_io = [_io("grad." + s.name, s.shape) for s in specs]
    meta = {
        "preset": preset,
        "d_model": cfg.d_model, "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads, "d_ff": cfg.d_ff, "seq_len": cfg.seq_len,
        "vocab": cfg.vocab, "num_params": M.num_params(cfg),
        "batch_per_worker": batch_per_worker,
        "params": [
            {"name": s.name, "shape": list(s.shape), "kind": s.kind}
            for s in specs
        ],
    }
    ex.export(
        f"fwdbwd_{preset}",
        lambda *a: M.train_step(list(a[:-1]), a[-1], cfg),
        p_specs + [tok],
        p_io + [tok_io],
        [_io("loss", ())] + grads_io,
        "fwdbwd", meta)
    ex.export(
        f"eval_{preset}",
        lambda *a: M.eval_loss(list(a[:-1]), a[-1], cfg),
        p_specs + [tok],
        p_io + [tok_io],
        [_io("loss", ())],
        "eval", meta)
    ex.export(
        f"predict_{preset}",
        lambda *a: M.predict(list(a[:-1]), a[-1], cfg),
        p_specs + [tok],
        p_io + [tok_io],
        [_io("argmax", (batch_per_worker, cfg.seq_len), I32)],
        "predict", meta)


def linear_shapes(preset: str):
    """Distinct (R, C) shapes of low-rank-eligible params, oriented so the
    projected (column) side is the smaller one — transposition to this
    orientation happens on the rust side."""
    cfg = M.PRESETS[preset]
    shapes = set()
    for s in M.param_specs(cfg):
        if s.kind != "linear":
            continue
        r, c = s.shape
        if c > r:
            r, c = c, r  # project the smaller dim; rust feeds Gᵀ
        shapes.add((r, c))
    return sorted(shapes)


def export_optimizer_graphs(ex: Exporter, preset: str, rank: int,
                            lr: float, mu: float):
    for (R, C) in linear_shapes(preset):
        r = min(rank, C)
        q_io = _io("dct_q", (C, C))
        ex.export(
            f"trion_{R}x{C}_r{r}",
            lambda m, g, q, _r=r: OG.trion_update(m, g, q, rank=_r, mu=mu),
            [spec((R, C)), spec((R, C)), spec((C, C))],
            [_io("m_prev", (R, C)), _io("grad", (R, C)), q_io],
            [_io("m_new", (R, C)), _io("o_full", (R, C)),
             _io("o_low", (R, r)), _io("idx", (r,), I32)],
            "trion_update",
            {"preset": preset, "R": R, "C": C, "rank": r, "mu": mu})
        ex.export(
            f"dctadamw_{R}x{C}_r{r}",
            lambda g, q, m, v, e, i, t, _r=r: OG.dct_adamw_update(
                g, q, m, v, e, i, t, rank=_r, lr=lr),
            [spec((R, C)), spec((C, C)), spec((R, r)), spec((R, r)),
             spec((R, C)), spec((r,), jnp.int32), spec((), jnp.float32)],
            [_io("grad", (R, C)), q_io, _io("m", (R, r)), _io("v", (R, r)),
             _io("ef", (R, C)), _io("idx_prev", (r,), I32),
             _io("step", ())],
            [_io("update_full", (R, C)), _io("m_new", (R, r)),
             _io("v_new", (R, r)), _io("ef_new", (R, C)),
             _io("idx", (r,), I32)],
            "dctadamw_update",
            {"preset": preset, "R": R, "C": C, "rank": r, "lr": lr})
        ex.export(
            f"dion_{R}x{C}_r{r}",
            lambda m, g, p: OG.dion_update(m, g, p, mu=mu),
            [spec((R, C)), spec((R, C)), spec((C, r))],
            [_io("m_prev", (R, C)), _io("grad", (R, C)), _io("q_prev", (C, r))],
            [_io("m_new", (R, C)), _io("o_full", (R, C)),
             _io("q_new", (C, r))],
            "dion_update",
            {"preset": preset, "R": R, "C": C, "rank": r, "mu": mu})


def export_kernel_smoke(ex: Exporter):
    """Small L1-kernel artifacts the rust integration tests execute to prove
    the pallas→HLO→PJRT path end to end."""
    from .kernels import dct as k_dct
    from .kernels import newton_schulz as k_ns
    R, C, r = 48, 32, 8
    ex.export(
        "kernel_dct_similarity_norms",
        lambda g, q: k_dct.dct_similarity_norms(g, q, "l2"),
        [spec((R, C)), spec((C, C))],
        [_io("g", (R, C)), _io("q", (C, C))],
        [_io("s", (R, C)), _io("norms", (C,))],
        "kernel", {"R": R, "C": C})
    ex.export(
        "kernel_newton_schulz",
        lambda x: (k_ns.newton_schulz(x, steps=5),),
        [spec((R, r))],
        [_io("x", (R, r))],
        [_io("o", (R, r))],
        "kernel", {"R": R, "r": r})
    ex.export(
        "kernel_makhoul_dct2",
        lambda g: (ref.makhoul_dct2(g),),
        [spec((R, C))],
        [_io("g", (R, C))],
        [_io("s", (R, C))],
        "kernel", {"R": R, "C": C})
    ex.export(
        "kernel_dct2_matrix",
        lambda: (ref.dct2_matrix(C),),
        [],
        [],
        [_io("q", (C, C))],
        "kernel", {"C": C})


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--presets", default="nano,micro,small,base")
    ap.add_argument("--opt-presets", default="nano,micro",
                    help="presets to export per-layer optimizer graphs for")
    ap.add_argument("--rank", type=int, default=32)
    ap.add_argument("--batch-per-worker", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mu", type=float, default=0.95)
    args = ap.parse_args()

    ex = Exporter(args.out_dir)
    t0 = time.time()
    for preset in args.presets.split(","):
        print(f"== model graphs: {preset} "
              f"({M.num_params(M.PRESETS[preset])/1e6:.2f}M params)")
        export_model_graphs(ex, preset, args.batch_per_worker)
    for preset in args.opt_presets.split(","):
        print(f"== optimizer graphs: {preset} rank={args.rank}")
        export_optimizer_graphs(ex, preset, args.rank, args.lr, args.mu)
    print("== kernel smoke artifacts")
    export_kernel_smoke(ex)
    ex.write_manifest({
        "version": 1,
        "defaults": {"rank": args.rank, "lr": args.lr, "mu": args.mu,
                     "batch_per_worker": args.batch_per_worker},
    })
    print(f"total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
