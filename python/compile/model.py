"""Layer-2: Llama-style transformer forward/backward in JAX.

This is the compute graph the rust coordinator trains. It is authored here,
AOT-lowered once by ``aot.py`` to HLO text per preset, and executed from
rust through PJRT — Python never runs on the training path.

Architecture (matches the paper's Llama family at reduced scale —
see DESIGN.md §Hardware-Adaptation for the scale substitution):

* byte-level vocab (256 + pad), untied LM head,
* pre-norm blocks: RMSNorm → causal multi-head attention with RoPE →
  RMSNorm → SwiGLU MLP,
* next-token cross-entropy loss averaged over all positions.

Parameters are handled as a *flat ordered list* of arrays so the rust side
can feed PJRT literals positionally; ``param_specs`` is the single source
of ordering truth and is serialized into ``artifacts/manifest.json``.
Each spec carries a ``kind`` tag that the rust optimizer uses for its
projection policy (2-D ``linear`` tensors get low-rank treatment; ``embed``
/ ``head`` / 1-D ``norm`` tensors always take full AdamW, as in GaLore /
LDAdam / Dion practice).
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple

import jax
import jax.numpy as jnp

VOCAB = 257  # 256 bytes + <pad>


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int
    vocab: int = VOCAB

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


# Presets sized for a 1-core-CPU testbed; the paper's 350M/800M/1.3B trio
# maps onto nano/micro/small with the same d_model-doubling progression,
# and `base` is the end-to-end example model.
PRESETS = {
    "nano": ModelConfig("nano", d_model=64, n_layers=2, n_heads=4, d_ff=176, seq_len=64),
    "micro": ModelConfig("micro", d_model=128, n_layers=4, n_heads=4, d_ff=344, seq_len=64),
    "small": ModelConfig("small", d_model=256, n_layers=6, n_heads=8, d_ff=688, seq_len=64),
    "base": ModelConfig("base", d_model=384, n_layers=8, n_heads=8, d_ff=1024, seq_len=128),
}


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: Tuple[int, ...]
    kind: str  # embed | head | norm | linear


def param_specs(cfg: ModelConfig) -> List[ParamSpec]:
    """Flat, ordered parameter inventory. Order here == literal order in the
    AOT artifact == buffer order on the rust side."""
    specs = [ParamSpec("embed", (cfg.vocab, cfg.d_model), "embed")]
    for l in range(cfg.n_layers):
        p = f"block{l}."
        specs += [
            ParamSpec(p + "attn_norm", (cfg.d_model,), "norm"),
            ParamSpec(p + "wq", (cfg.d_model, cfg.d_model), "linear"),
            ParamSpec(p + "wk", (cfg.d_model, cfg.d_model), "linear"),
            ParamSpec(p + "wv", (cfg.d_model, cfg.d_model), "linear"),
            ParamSpec(p + "wo", (cfg.d_model, cfg.d_model), "linear"),
            ParamSpec(p + "mlp_norm", (cfg.d_model,), "norm"),
            ParamSpec(p + "w_gate", (cfg.d_model, cfg.d_ff), "linear"),
            ParamSpec(p + "w_up", (cfg.d_model, cfg.d_ff), "linear"),
            ParamSpec(p + "w_down", (cfg.d_ff, cfg.d_model), "linear"),
        ]
    specs += [
        ParamSpec("final_norm", (cfg.d_model,), "norm"),
        ParamSpec("lm_head", (cfg.d_model, cfg.vocab), "head"),
    ]
    return specs


def num_params(cfg: ModelConfig) -> int:
    return sum(math.prod(s.shape) for s in param_specs(cfg))


def init_params(cfg: ModelConfig, key: jax.Array) -> List[jnp.ndarray]:
    """Scaled-normal init (0.02 embed/linear, zeros-safe norms)."""
    params = []
    for spec in param_specs(cfg):
        key, sub = jax.random.split(key)
        if spec.kind == "norm":
            params.append(jnp.ones(spec.shape, jnp.float32))
        else:
            fan_in = spec.shape[0] if len(spec.shape) == 2 else cfg.d_model
            std = 0.02 if spec.kind in ("embed", "head") else 1.0 / math.sqrt(fan_in)
            params.append(std * jax.random.normal(sub, spec.shape, jnp.float32))
    return params


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * w


def rope_tables(seq_len: int, head_dim: int):
    """RoPE cos/sin tables, (S, head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    t = jnp.arange(seq_len, dtype=jnp.float32)
    ang = jnp.outer(t, freqs)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (B, H, S, D). Rotates interleaved half-pairs."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[None, None, :, :]
    s = sin[None, None, :, :]
    return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c], axis=-1)


def _unpack(params: List[jnp.ndarray], cfg: ModelConfig):
    it = iter(params)
    embed = next(it)
    blocks = []
    for _ in range(cfg.n_layers):
        blocks.append({
            "attn_norm": next(it), "wq": next(it), "wk": next(it),
            "wv": next(it), "wo": next(it), "mlp_norm": next(it),
            "w_gate": next(it), "w_up": next(it), "w_down": next(it),
        })
    final_norm = next(it)
    lm_head = next(it)
    return embed, blocks, final_norm, lm_head


def forward(params: List[jnp.ndarray], tokens: jnp.ndarray,
            cfg: ModelConfig) -> jnp.ndarray:
    """Logits (B, S, V) for int32 tokens (B, S)."""
    embed, blocks, final_norm, lm_head = _unpack(params, cfg)
    b, s = tokens.shape
    h = embed[tokens]                                     # (B, S, d)
    cos, sin = rope_tables(s, cfg.head_dim)
    mask = jnp.tril(jnp.ones((s, s), jnp.float32))
    neg = jnp.finfo(jnp.float32).min
    for blk in blocks:
        x = rmsnorm(h, blk["attn_norm"])
        q = (x @ blk["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        k = (x @ blk["wk"]).reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        v = (x @ blk["wv"]).reshape(b, s, cfg.n_heads, cfg.head_dim).transpose(0, 2, 1, 3)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(cfg.head_dim)
        att = jnp.where(mask[None, None] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        ctx = jnp.einsum("bhqk,bhkd->bhqd", att, v)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, cfg.d_model)
        h = h + ctx @ blk["wo"]
        x = rmsnorm(h, blk["mlp_norm"])
        gate = jax.nn.silu(x @ blk["w_gate"])
        up = x @ blk["w_up"]
        h = h + (gate * up) @ blk["w_down"]
    h = rmsnorm(h, final_norm)
    return h @ lm_head


def loss_fn(params: List[jnp.ndarray], tokens: jnp.ndarray,
            cfg: ModelConfig) -> jnp.ndarray:
    """Mean next-token cross-entropy over positions 0..S-2."""
    logits = forward(params, tokens, cfg)[:, :-1, :]
    targets = tokens[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def train_step(params: List[jnp.ndarray], tokens: jnp.ndarray,
               cfg: ModelConfig):
    """(loss, grads...) — the pure function lowered per preset to HLO.

    The rust coordinator owns parameters and optimizer state; this graph is
    stateless so the same artifact serves every optimizer and every DDP
    worker (each worker feeds its own microbatch shard).
    """
    loss, grads = jax.value_and_grad(lambda p: loss_fn(p, tokens, cfg))(params)
    return (loss, *grads)


def eval_loss(params: List[jnp.ndarray], tokens: jnp.ndarray,
              cfg: ModelConfig):
    """(loss,) — forward-only artifact for validation perplexity."""
    return (loss_fn(params, tokens, cfg),)


def predict(params: List[jnp.ndarray], tokens: jnp.ndarray,
            cfg: ModelConfig):
    """(argmax,) — per-position greedy predictions (B, S) int32, for the
    fine-tuning exact-match metric (Tables 7–8 analog)."""
    logits = forward(params, tokens, cfg)
    return (jnp.argmax(logits, axis=-1).astype(jnp.int32),)
