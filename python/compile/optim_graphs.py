"""Layer-2: per-layer optimizer update graphs (Trion / DCT-AdamW).

These are the paper's *contribution* compiled as standalone HLO artifacts:
one graph per distinct layer shape of a preset, calling the Layer-1 Pallas
kernels (fused DCT similarity + norms, single-block Newton–Schulz, fused
AdamW). The rust coordinator owns all state buffers and threads them
through these pure functions; the ZeRO owner of a layer executes the graph
and broadcasts the low-rank result (§2.3 "Communication in Distributed
Training").

Projection side is chosen per shape exactly as the paper prescribes —
compress the *smallest* dimension:

* ``C ≤ R``  → right-projection (similarities ``S = B·Q``, ``Q ∈ R^{C×C}``)
* ``C > R``  → left-projection (applied to ``Bᵀ``; rust transposes at the
               call boundary so the graphs below only implement the right
               case — this mirrors Dion's per-layer shard orientation
               decision).
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import adamw as k_adamw
from .kernels import dct as k_dct
from .kernels import newton_schulz as k_ns
from .kernels import ref


def _select(sim: jnp.ndarray, norms: jnp.ndarray, r: int) -> jnp.ndarray:
    """Top-r column indices by pre-computed norms (ascending order).

    argsort-based (not ``lax.top_k``) so the lowered HLO stays within the
    XLA-0.5.1 text grammar the rust loader parses — see ref.py.
    """
    order = jnp.argsort(-norms, stable=True)
    return jnp.sort(order[:r])


def trion_update(m_prev, g, q, *, rank: int, mu: float = 0.95,
                 ns_steps: int = 5, norm: str = "l2"):
    """Algorithm 1 lines 4–12 for one layer (right-projection).

    Inputs:  ``m_prev (R×C)``, ``g (R×C)``, ``q (C×C)`` DCT-II matrix.
    Outputs: ``(m_new (R×C), o_full (R×C), o_low (R×r), idx (r,))``.

    ``o_low``/``idx`` are what the ZeRO owner broadcasts (r·(R+1) values
    instead of R·C); receivers reconstruct ``O = o_low · Q[:, idx]ᵀ``
    locally from their DCT replica.
    """
    b_full = m_prev + g
    s, norms = k_dct.dct_similarity_norms(b_full, q, norm)      # L1 kernel
    idx = _select(s, norms, rank)
    b_low = k_dct.gather_columns(s, idx)                        # L1 kernel
    q_r = k_dct.gather_columns(q, idx)                          # L1 kernel
    m_new = b_full - (1.0 - mu) * (b_low @ q_r.T)
    o_low = k_ns.newton_schulz(b_low, steps=ns_steps)           # L1 kernel
    o_full = o_low @ q_r.T
    return m_new, o_full, o_low, idx.astype(jnp.int32)


def dct_adamw_update(g, q, m, v, ef, idx_prev, step, *, rank: int,
                     lr: float, beta1: float = 0.9, beta2: float = 0.999,
                     eps: float = 1e-8, norm: str = "l2"):
    """Algorithms 2–3 for one layer (right-projection, T_u = 1).

    Inputs:  ``g (R×C)``, ``q (C×C)``, subspace moments ``m, v (R×r)``,
             error-feedback ``ef (R×C)``, ``idx_prev (r,) int32``,
             ``step`` scalar f32 (1-based; step==1 ⇒ identity rotation).
    Outputs: ``(update_full (R×C), m', v', ef', idx')``.
    """
    g = g + ef
    s, norms = k_dct.dct_similarity_norms(g, q, norm)           # L1 kernel
    idx = _select(s, norms, rank)
    q_crt = k_dct.gather_columns(q, idx)
    q_prev = k_dct.gather_columns(q, idx_prev)
    rot = q_prev.T @ q_crt                                      # r×r
    eye = jnp.eye(rank, dtype=g.dtype)
    rot = jnp.where(step <= 1.0, eye, rot)
    g_low = k_dct.gather_columns(s, idx)
    ef_new = g - g_low @ q_crt.T
    m = m @ rot
    v = jnp.abs(v @ rot)
    # Fused AdamW on the r-dimensional subspace buffers (params start at 0:
    # the kernel returns the *negative displacement* we need).
    zero_p = jnp.zeros_like(g_low)
    p_new, m_new, v_new = k_adamw.adamw_update(
        zero_p, g_low, m, v, step,
        lr=lr, beta1=beta1, beta2=beta2, eps=eps, weight_decay=0.0)
    u_low = -p_new                                              # lr·m̂/(√v̂+ε)
    update_full = u_low @ q_crt.T
    return update_full, m_new, v_new, ef_new, idx.astype(jnp.int32)


def dion_update(m_prev, g, p_prev, *, mu: float = 0.95):
    """Dion baseline (power-iteration + QR) as an AOT graph, for the
    artifact-level Trion-vs-Dion comparison. Mirrors ``ref.dion_layer_update``."""
    return ref.dion_layer_update(m_prev, g, p_prev, mu=mu)
