"""Mirror of rust/benches/bench_mem.rs byte accounting (exact, deterministic).

Regenerate the authoritative file with `make bench-mem` in a
toolchain-equipped environment; this mirror exists because the build
container has no cargo.
"""
import json

RANK = 32

def model(name, d, blocks, vocab):
    ff = d * 11 // 4
    metas = [("embed", vocab, d, "Embed"), ("head", d, vocab, "Head")]
    for l in range(blocks):
        for w in ["wq", "wk", "wv", "wo"]:
            metas.append((f"b{l}.{w}", d, d, "Linear"))
        metas.append((f"b{l}.gate", d, ff, "Linear"))
        metas.append((f"b{l}.down", ff, d, "Linear"))
        metas.append((f"b{l}.norm", 1, d, "Norm"))
    return name, metas

def oriented(rows, cols):
    return (cols, rows) if cols > rows else (rows, cols)

def store_bytes(elems, dtype):
    return {"f32": elems * 4, "bf16": elems * 2, "q8": elems + 4}[dtype]

def adam_state(rows, cols, dtype):
    return 2 * store_bytes(rows * cols, dtype)

# preset axes (OptimizerSpec::from_kind with default OptimizerConfig: ef_mode=q8)
PRESETS = {
    "dct-adamw": dict(source="dct", rotation="fixed", residual=("ef", "q8"), rule="adamw"),
    "trion":     dict(source="dct", rotation="none",  residual=None,          rule="ns"),
    "galore":    dict(source="svd", rotation="none",  residual=None,          rule="adamw"),
    "fira":      dict(source="dct", rotation="none",  residual=None,          rule="adamw"),
    "frugal":    dict(source="dct", rotation="none",  residual=None,          rule="adamw"),
    "ldadamw":   dict(source="block_power", rotation="dense", residual=("ef", "f32"), rule="adamw"),
}

def preset_total(metas, preset, dtype):
    ax = PRESETS[preset]
    total = 0
    shared_dims = set()
    for (_, rows, cols, kind) in metas:
        if kind != "Linear":
            total += adam_state(rows, cols, dtype)
            continue
        rr, cc = oriented(rows, cols)
        r = min(RANK, cc)
        # rule state
        if ax["rule"] == "adamw":
            total += 2 * store_bytes(rr * r, dtype)   # m + v (R×r)
        else:
            total += store_bytes(rr * cc, dtype)       # NS momentum (R×C)
        # source state
        if ax["source"] == "dct":
            total += r * 4                             # indices
            shared_dims.add(cc)
        else:                                          # svd / block_power
            total += cc * r * 4                        # dense projector (f32)
        # rotation state
        if ax["rotation"] == "fixed":
            total += r * 4                             # idx_prev
        elif ax["rotation"] == "dense":
            total += cc * r * 4                        # prev basis (f32)
        # residual state
        if ax["residual"] is not None:
            _, ef = ax["residual"]
            total += rr * cc * 4 if ef == "f32" else rr * cc + 4
    for dim in shared_dims:
        total += dim * dim * 4                         # shared DCT matrix
    return total

records = []
for (name, metas) in [model("bench-small", 128, 4, 256), model("bench-large", 256, 8, 256)]:
    params = sum(r * c for (_, r, c, _) in metas)
    adam_f32 = sum(adam_state(r, c, "f32") for (_, r, c, _) in metas)
    print(f"{name}: {params} params, adam(f32) = {adam_f32} bytes")
    def push(opt, dtype, total):
        ratio = total / adam_f32
        print(f"  {opt:<10} state={dtype:<4} {total:>12} bytes  ({ratio*100:5.1f}% of adam-f32)")
        records.append({
            "model": name, "params": params, "optimizer": opt,
            "state_dtype": dtype, "rank": RANK, "total_bytes": total,
            "adam_f32_bytes": adam_f32, "ratio_vs_adam_f32": round(ratio, 6),
        })
    for dtype in ["f32", "bf16", "q8"]:
        push("adamw", dtype, sum(adam_state(r, c, dtype) for (_, r, c, _) in metas))
        for preset in PRESETS:
            push(preset, dtype, preset_total(metas, preset, dtype))
    print()

import os
out = os.path.join(os.path.dirname(__file__), "..", "..", "rust", "BENCH_MEM.json")
with open(out, "w") as f:
    json.dump({"version": 1, "records": records}, f, indent=1)
    f.write("\n")
print(f"wrote {out}")
