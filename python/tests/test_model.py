"""Layer-2 correctness: model shapes, loss sanity, grads, optimizer graphs."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import optim_graphs as OG
from compile.kernels import ref


@pytest.fixture(scope="module")
def nano():
    cfg = M.PRESETS["nano"]
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def tokens_for(cfg, batch=2, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 256, size=(batch, cfg.seq_len)),
                       dtype=jnp.int32)


def test_param_specs_cover_init(nano):
    cfg, params = nano
    specs = M.param_specs(cfg)
    assert len(specs) == len(params)
    for s, p in zip(specs, params):
        assert tuple(p.shape) == s.shape


def test_num_params_matches(nano):
    cfg, params = nano
    assert M.num_params(cfg) == sum(int(np.prod(p.shape)) for p in params)


def test_forward_shapes(nano):
    cfg, params = nano
    toks = tokens_for(cfg)
    logits = M.forward(params, toks, cfg)
    assert logits.shape == (2, cfg.seq_len, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_initial_loss_near_uniform(nano):
    """Random init ⇒ loss ≈ log(vocab)."""
    cfg, params = nano
    loss = float(M.loss_fn(params, tokens_for(cfg), cfg))
    assert abs(loss - np.log(cfg.vocab)) < 1.0


def test_train_step_outputs(nano):
    cfg, params = nano
    outs = M.train_step(params, tokens_for(cfg), cfg)
    assert len(outs) == 1 + len(params)
    for g, p in zip(outs[1:], params):
        assert g.shape == p.shape
        assert np.isfinite(np.asarray(g)).all()


def test_gradient_descends(nano):
    """A few SGD steps on a fixed batch must reduce the loss."""
    cfg, params = nano
    toks = tokens_for(cfg)
    step = jax.jit(lambda ps: M.train_step(ps, toks, cfg))
    loss0 = None
    ps = list(params)
    for _ in range(5):
        outs = step(ps)
        loss = float(outs[0])
        if loss0 is None:
            loss0 = loss
        ps = [p - 0.05 * g for p, g in zip(ps, outs[1:])]
    assert float(M.loss_fn(ps, toks, cfg)) < loss0 - 0.05


def test_rope_preserves_norm():
    cos, sin = M.rope_tables(16, 8)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((1, 2, 16, 8)),
                    dtype=jnp.float32)
    y = M.apply_rope(x, cos, sin)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)


def test_causality(nano):
    """Changing a future token must not change past logits."""
    cfg, params = nano
    toks = tokens_for(cfg, batch=1)
    logits_a = np.asarray(M.forward(params, toks, cfg))
    toks_b = toks.at[0, -1].set((toks[0, -1] + 1) % 256)
    logits_b = np.asarray(M.forward(params, toks_b, cfg))
    np.testing.assert_allclose(logits_a[0, :-1], logits_b[0, :-1], atol=1e-5)


# ---------------------------------------------------------------------------
# Optimizer graphs vs oracle
# ---------------------------------------------------------------------------

def test_trion_graph_matches_ref():
    rng = np.random.default_rng(0)
    R, C, r = 40, 24, 6
    m = rng.standard_normal((R, C)).astype(np.float32)
    g = rng.standard_normal((R, C)).astype(np.float32)
    q = np.asarray(ref.dct2_matrix(C))
    m_new, o_full, o_low, idx = OG.trion_update(
        jnp.asarray(m), jnp.asarray(g), jnp.asarray(q), rank=r)
    want_m, want_o, want_idx = ref.trion_layer_update(
        jnp.asarray(m), jnp.asarray(g), jnp.asarray(q), rank=r)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(want_idx))
    np.testing.assert_allclose(np.asarray(m_new), np.asarray(want_m),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(o_full), np.asarray(want_o),
                               atol=1e-4, rtol=1e-4)
    # broadcast identity: O == o_low · Q[:, idx]ᵀ
    np.testing.assert_allclose(
        np.asarray(o_full),
        np.asarray(o_low) @ q[:, np.asarray(idx)].T, atol=1e-4, rtol=1e-4)


def test_dct_adamw_graph_matches_ref():
    rng = np.random.default_rng(1)
    R, C, r = 32, 20, 5
    g = rng.standard_normal((R, C)).astype(np.float32)
    q = np.asarray(ref.dct2_matrix(C))
    m = rng.standard_normal((R, r)).astype(np.float32)
    v = np.abs(rng.standard_normal((R, r))).astype(np.float32)
    ef = rng.standard_normal((R, C)).astype(np.float32)
    idx_prev = np.sort(rng.choice(C, r, replace=False)).astype(np.int32)
    kw = dict(rank=r, lr=1e-3)
    got = OG.dct_adamw_update(jnp.asarray(g), jnp.asarray(q), jnp.asarray(m),
                              jnp.asarray(v), jnp.asarray(ef),
                              jnp.asarray(idx_prev),
                              jnp.asarray(7.0, jnp.float32), **kw)
    want = ref.dct_adamw_layer_update(
        jnp.asarray(g), jnp.asarray(q), jnp.asarray(m), jnp.asarray(v),
        jnp.asarray(ef), jnp.asarray(idx_prev), rank=r, lr=1e-3, step=7,
        first=False)
    names = ["update", "m", "v", "ef", "idx"]
    for n, a, b in zip(names, got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-4, err_msg=n)


def test_dct_adamw_graph_first_step_identity_rotation():
    rng = np.random.default_rng(2)
    R, C, r = 16, 12, 4
    g = rng.standard_normal((R, C)).astype(np.float32)
    q = np.asarray(ref.dct2_matrix(C))
    m = np.zeros((R, r), np.float32)
    v = np.zeros((R, r), np.float32)
    ef = np.zeros((R, C), np.float32)
    idx_prev = np.zeros((r,), np.int32)
    got = OG.dct_adamw_update(jnp.asarray(g), jnp.asarray(q), jnp.asarray(m),
                              jnp.asarray(v), jnp.asarray(ef),
                              jnp.asarray(idx_prev),
                              jnp.asarray(1.0, jnp.float32), rank=r, lr=1e-2)
    want = ref.dct_adamw_layer_update(
        jnp.asarray(g), jnp.asarray(q), jnp.asarray(m), jnp.asarray(v),
        jnp.asarray(ef), jnp.asarray(idx_prev), rank=r, lr=1e-2, step=1,
        first=True)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=2e-5)


def test_dion_graph_error_feedback_shrinks_momentum():
    rng = np.random.default_rng(3)
    R, C, r = 24, 16, 4
    m = np.zeros((R, C), np.float32)
    g = rng.standard_normal((R, C)).astype(np.float32)
    p = np.linalg.qr(rng.standard_normal((C, r)))[0].astype(np.float32)
    m_new, o_full, q_new = OG.dion_update(
        jnp.asarray(m), jnp.asarray(g), jnp.asarray(p))
    # persistent state: unit-norm columns, shape C×r
    qn = np.asarray(q_new)
    assert qn.shape == (C, r)
    np.testing.assert_allclose(np.linalg.norm(qn, axis=0), np.ones(r),
                               atol=1e-4)
    # momentum keeps the projection residual plus mu-weighted captured part
    assert np.linalg.norm(np.asarray(m_new)) < np.linalg.norm(g) * 1.01


def test_linear_shapes_orientation():
    from compile import aot
    for (R, C) in aot.linear_shapes("micro"):
        assert R >= C
