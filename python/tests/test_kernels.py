"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

hypothesis sweeps shapes (including non-tile-multiple and degenerate ones)
and dtypes; assert_allclose against the oracle is the core signal.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import adamw as k_adamw
from compile.kernels import dct as k_dct
from compile.kernels import newton_schulz as k_ns
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)

dims = st.integers(min_value=2, max_value=160)
small_dims = st.integers(min_value=2, max_value=64)


def rand(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


# ---------------------------------------------------------------------------
# DCT matrix properties
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [2, 3, 5, 8, 17, 64, 96, 128, 257])
def test_dct_matrix_orthogonal(n):
    q = np.asarray(ref.dct2_matrix(n))
    np.testing.assert_allclose(q.T @ q, np.eye(n), atol=2e-5)
    np.testing.assert_allclose(q @ q.T, np.eye(n), atol=2e-5)


def test_dct2_is_dct3_transpose():
    np.testing.assert_array_equal(
        np.asarray(ref.dct2_matrix(32)), np.asarray(ref.dct3_matrix(32)).T)


def test_dct3_matches_closed_form():
    n = 16
    q = np.asarray(ref.dct3_matrix(n))
    for i in range(n):
        for j in range(n):
            v = np.sqrt(2.0 / n) * np.cos(i * (2 * j + 1) * np.pi / (2 * n))
            if i == 0:
                v /= np.sqrt(2.0)
            assert abs(q[i, j] - v) < 1e-6


# ---------------------------------------------------------------------------
# Makhoul fast DCT == matmul DCT (Appendix D)
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(r=small_dims, c=small_dims)
def test_makhoul_equals_matmul(r, c):
    rng = np.random.default_rng(r * 1000 + c)
    g = rand(rng, r, c)
    want = g @ np.asarray(ref.dct2_matrix(c))
    got = np.asarray(ref.makhoul_dct2(jnp.asarray(g)))
    np.testing.assert_allclose(got, want, atol=5e-4)


def test_makhoul_permutation():
    x = jnp.asarray([[1., 2., 3., 4., 5., 6.]])
    got = np.asarray(ref.makhoul_permute(x))[0]
    np.testing.assert_array_equal(got, [1, 3, 5, 6, 4, 2])


def test_makhoul_odd_length():
    x = jnp.asarray([[1., 2., 3., 4., 5.]])
    got = np.asarray(ref.makhoul_permute(x))[0]
    np.testing.assert_array_equal(got, [1, 3, 5, 4, 2])


# ---------------------------------------------------------------------------
# Pallas DCT similarity kernels vs oracle
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(r=dims, c=dims)
def test_pallas_similarity(r, c):
    rng = np.random.default_rng(r * 7 + c)
    g, q = rand(rng, r, c), rand(rng, c, c)
    want = g @ q
    got = np.asarray(k_dct.dct_similarity(jnp.asarray(g), jnp.asarray(q)))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(r=dims, c=dims, norm=st.sampled_from(["l1", "l2"]))
def test_pallas_similarity_norms_fused(r, c, norm):
    rng = np.random.default_rng(r * 13 + c)
    g, q = rand(rng, r, c), rand(rng, c, c)
    s, nrm = k_dct.dct_similarity_norms(jnp.asarray(g), jnp.asarray(q), norm)
    want_s = g @ q
    want_n = np.asarray(ref.column_norms(jnp.asarray(want_s), norm))
    np.testing.assert_allclose(np.asarray(s), want_s, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(nrm), want_n, atol=3e-3, rtol=1e-3)


@settings(max_examples=10, deadline=None)
@given(r=dims, c=st.integers(min_value=8, max_value=96),
       k=st.integers(min_value=1, max_value=8))
def test_pallas_gather_columns(r, c, k):
    k = min(k, c)
    rng = np.random.default_rng(r + c + k)
    src = rand(rng, r, c)
    idx = rng.choice(c, size=k, replace=False).astype(np.int32)
    got = np.asarray(k_dct.gather_columns(jnp.asarray(src), jnp.asarray(idx)))
    np.testing.assert_array_equal(got, src[:, idx])


# ---------------------------------------------------------------------------
# Dynamic column selection (§2.1) + §4.1 contractiveness
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(n=st.integers(min_value=4, max_value=64),
       m=st.integers(min_value=4, max_value=64),
       frac=st.sampled_from([0.25, 0.5]))
def test_selection_contractive(n, m, frac):
    """‖G − Q_r Q_rᵀ G‖²_F ≤ (1 − r/n)·‖G‖²_F for norm-based selection."""
    r = max(1, int(n * frac))
    rng = np.random.default_rng(n * 100 + m)
    g = rand(rng, n, m)
    q = np.asarray(ref.dct2_matrix(n))
    # left-projection: select columns of Q by alignment with rows of Gᵀ
    idx = np.asarray(ref.dynamic_column_selection(jnp.asarray(g.T @ q), r))
    q_r = q[:, idx]
    err = float(ref.reconstruction_error_sq(jnp.asarray(g), jnp.asarray(q_r)))
    bound = (1.0 - r / n) * float(np.sum(g * g))
    assert err <= bound + 1e-3


def test_selection_optimal_among_subsets():
    """Norm-based top-r is the optimal column subset (§4.1): brute-force all
    subsets on a small instance and compare reconstruction errors."""
    from itertools import combinations
    rng = np.random.default_rng(0)
    n, m, r = 6, 5, 3
    g = rand(rng, n, m)
    q = np.asarray(ref.dct2_matrix(n))
    sel = np.asarray(ref.dynamic_column_selection(jnp.asarray(g.T @ q), r))
    err_sel = float(ref.reconstruction_error_sq(
        jnp.asarray(g), jnp.asarray(q[:, sel])))
    best = min(
        float(ref.reconstruction_error_sq(jnp.asarray(g), jnp.asarray(q[:, list(c)])))
        for c in combinations(range(n), r))
    assert err_sel <= best + 1e-5


def test_selection_deterministic_sorted():
    rng = np.random.default_rng(3)
    s = rand(rng, 10, 12)
    idx = np.asarray(ref.dynamic_column_selection(jnp.asarray(s), 5))
    assert list(idx) == sorted(idx)
    assert len(set(idx.tolist())) == 5


# ---------------------------------------------------------------------------
# Newton–Schulz kernel vs oracle + orthogonalization property
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(r=st.integers(min_value=8, max_value=96),
       c=st.integers(min_value=2, max_value=16))
def test_pallas_newton_schulz_matches_ref(r, c):
    rng = np.random.default_rng(r * 31 + c)
    x = rand(rng, r, c)
    want = np.asarray(ref.newton_schulz(jnp.asarray(x)))
    got = np.asarray(k_ns.newton_schulz(jnp.asarray(x)))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_newton_schulz_pushes_singular_values_to_one():
    rng = np.random.default_rng(7)
    x = rand(rng, 64, 8)
    o = np.asarray(ref.newton_schulz(jnp.asarray(x), steps=10))
    sv = np.linalg.svd(o, compute_uv=False)
    assert np.all(sv > 0.6) and np.all(sv < 1.4)


def test_newton_schulz_wide_input():
    rng = np.random.default_rng(8)
    x = rand(rng, 8, 64)  # wide: kernel must transpose internally
    want = np.asarray(ref.newton_schulz(jnp.asarray(x)))
    got = np.asarray(k_ns.newton_schulz(jnp.asarray(x)))
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# Fused AdamW kernel vs oracle
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(r=dims, c=small_dims, step=st.integers(min_value=1, max_value=1000))
def test_pallas_adamw_matches_ref(r, c, step):
    rng = np.random.default_rng(r + c + step)
    p, g, m, v = rand(rng, r, c), rand(rng, r, c), rand(rng, r, c), np.abs(rand(rng, r, c))
    kw = dict(lr=1e-3, beta1=0.9, beta2=0.999, eps=1e-8, weight_decay=0.1)
    want = ref.adamw_update(jnp.asarray(p), jnp.asarray(g), jnp.asarray(m),
                            jnp.asarray(v), step=step, **kw)
    got = k_adamw.adamw_update(jnp.asarray(p), jnp.asarray(g), jnp.asarray(m),
                               jnp.asarray(v), jnp.asarray(float(step)), **kw)
    for w, g_ in zip(want, got):
        np.testing.assert_allclose(np.asarray(g_), np.asarray(w),
                                   atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# EF quantization round-trip
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(r=small_dims, c=small_dims)
def test_ef_quantization_bounded_error(r, c):
    rng = np.random.default_rng(r * c)
    x = rand(rng, r, c)
    q, scale = ref.quantize_ef_u8(jnp.asarray(x))
    back = np.asarray(ref.dequantize_ef_u8(q, scale))
    assert np.abs(back - x).max() <= float(scale) * 0.5 + 1e-6
